"""Paper-validation pass -> experiments/paper_validation.json (incremental).

Reproduces (at CPU-feasible scale) the claims of: Table 2 (accuracy:
Random/Ordered/Invariant x r), Fig 4a (straggler time), Fig 4b (dynamic
stragglers), Fig 5 (scalability), Fig 6 (invariant evolution), Table 3
(threshold sweep). Results are flushed after every experiment. Scale knobs
are sized for a single CPU core; pass --full for the bigger pass.
"""
import json
import sys
import time

from benchmarks import paper_experiments as pe

FULL = "--full" in sys.argv
OUT = "experiments/paper_validation.json"
results = {}
t0 = time.time()


def flush(name, value):
    results[name] = value
    results["wall_s"] = round(time.time() - t0, 1)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=2, default=str)
    print(name, "done", results["wall_s"], flush=True)


flush("fig6_invariant_evolution",
      pe.fig6_invariant_evolution(rounds=20, n_data=800))
flush("fig4a_straggler_time", pe.fig4a_straggler_time(rounds=10, n_data=600))
flush("fig4b_dynamic", pe.fig4b_dynamic_stragglers(rounds=16, n_data=500))
flush("table3_threshold",
      pe.table3_threshold(rounds=6, n_data=600,
                          thresholds=(0.002, 0.005, 0.01, 0.02, 0.05)))

rates = (0.95, 0.75, 0.5) if FULL else (0.75, 0.5)
t2 = {f"{m}@r{r}": v for (m, r), v in pe.table2_accuracy(
    rates=rates, rounds=30 if FULL else 20,
    n_data=1500 if FULL else 1000,
    seeds=(0, 1) if FULL else (0,)).items()}
flush("table2_accuracy_femnist", t2)

flush("fig5_scalability",
      pe.fig5_scalability(n_clients=16 if FULL else 10,
                          rounds=15 if FULL else 10,
                          n_data=2000 if FULL else 1200))

t2s = {f"{m}@r{r}": v for (m, r), v in pe.table2_accuracy(
    workload="shakespeare", rates=(0.75,), rounds=15, n_data=1000,
    seeds=(0,)).items()}
flush("table2_accuracy_shakespeare", t2s)
print("written", OUT)
