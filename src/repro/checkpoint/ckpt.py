"""Flat-npz checkpointing of arbitrary pytrees + JSON metadata."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat):
    tree = {}
    for key, val in flat.items():
        parts = []
        for seg in key.split("/"):
            parts.extend(_resplit(seg))
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _listify(tree)


def _resplit(seg):
    out = []
    while "#" in seg:
        head, _, rest = seg.partition("#")
        num, _, seg2 = rest.partition("/")
        if head:
            out.append(head)
        out.append(("#", int(num)))
        seg = seg2
        if not seg:
            return out
    out.append(seg)
    return out


def _listify(node):
    if isinstance(node, dict):
        keys = list(node.keys())
        if keys and all(isinstance(k, tuple) and k[0] == "#" for k in keys):
            n = max(k[1] for k in keys) + 1
            return [_listify(node[("#", i)]) for i in range(n)]
        return {k: _listify(v) for k, v in node.items()}
    return node


def save_checkpoint(path: str, tree, meta: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    if meta is not None:
        with open(path.rsplit(".npz", 1)[0] + ".json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str):
    if not path.endswith(".npz"):
        path += ".npz"
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)
