"""Repo-specific AST lint: the JAX footguns this codebase actually hits.

Every rule exists because some version of the bug shipped (or nearly did)
in this repo; the fix-it messages point at the idiom the codebase settled
on rather than generic advice. Rules:

  FLD101 tracer-branch     Python ``if``/``while`` on a jnp expression —
                           under jit the test is a tracer and raises
                           ConcretizationTypeError (or silently freezes the
                           branch at trace time under vmap batching).
  FLD102 loop-jnp          jnp calls inside a Python loop in a jit-traced
                           function: the loop unrolls into the jaxpr at
                           trace time (fleet.py's intentional unroll is
                           opt-in via disable; see DESIGN.md §8).
  FLD103 np-float-op       np.sqrt/np.exp/... in a jax-importing module:
                           numpy float ops return *strong* np.float64
                           scalars that upcast jax arrays when x64 is
                           enabled (math.* returns weak Python floats and
                           never promotes; jnp.* stays on device).
  FLD104 factory-dtype     dtype-less float factory (jnp.zeros/ones/full/
                           linspace/eye): defaults to float64 under x64 and
                           ignores the config's param_dtype either way.
  FLD105 host-sync         .item()/np.asarray/np.array/jax.device_get
                           inside a statically jit-traced function: a
                           device→host sync (or a trace error) on the hot
                           path.
  FLD106 unregistered-policy  BasePolicy subclass without
                           @register_policy: invisible to get_policy(), so
                           the FL loop and serving engine can't resolve it.
  FLD107 missing-donate    jax.jit(<step function>) without donate_argnums:
                           train/decode steps that thread params/opt-state/
                           caches through themselves double their peak
                           memory unless the dead input buffers are
                           donated. Pass launch.sharding.donate_args(...)
                           (gated off CPU) or an explicit () to declare
                           nothing is donatable.

Suppression: append ``# fluidlint: disable=FLD103`` (comma-list, or
``all``) to the offending line, or put
``# fluidlint: disable-file=FLD102`` in the first ten lines of the file.

Scope notes. "jit-traced function" (FLD102/FLD105) means statically
visible tracing only: a function decorated with jax.jit /
functools.partial(jax.jit, ...) or whose name is passed to
jax.jit/vmap/grad/value_and_grad/checkpoint/lax.scan *in the same module*,
including everything nested inside it. Factories built and returned for
the caller to jit (launch/steps.py) are out of reach — the contracts pass
(analysis/contracts.py) covers those dynamically. Bare Python float
literals are *not* flagged: jax keeps them weak-typed, so ``x * 0.5``
never promotes — the promotion hazards are strong np scalars (FLD103) and
dtype-less factories (FLD104).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    summary: str
    fixit: str


RULES: Dict[str, Rule] = {r.id: r for r in [
    Rule("FLD101", "tracer-branch",
         "Python if/while on a jnp expression",
         "use jnp.where / jax.lax.cond / jax.lax.while_loop (or hoist the "
         "test to host-side numpy before tracing)"),
    Rule("FLD102", "loop-jnp",
         "jnp call inside a Python loop in a jit-traced function",
         "use jax.lax.scan / fori_loop, or suppress if the unroll is "
         "intentional and bounded (DESIGN.md §8)"),
    Rule("FLD103", "np-float-op",
         "numpy float op in a jax-importing module",
         "use math.* for Python scalars (stays weak-typed) or jnp.* for "
         "arrays; np float ops return strong np.float64 scalars that "
         "upcast jax arrays under x64"),
    Rule("FLD104", "factory-dtype",
         "dtype-less float jnp factory",
         "pass dtype= explicitly (float factories default to f64 under "
         "x64 and ignore the config's param_dtype)"),
    Rule("FLD105", "host-sync",
         "host sync inside a jit-traced function",
         "move .item()/np.asarray/device_get outside the traced function; "
         "inside a trace they either error or silently round-trip to host"),
    Rule("FLD106", "unregistered-policy",
         "BasePolicy subclass not registered",
         "decorate with @register_policy(\"<name>\") so "
         "core.dropout.get_policy can resolve it"),
    Rule("FLD107", "missing-donate",
         "jax.jit on a step function without donate_argnums",
         "pass donate_argnums=launch.sharding.donate_args(...) (returns () "
         "on CPU where donation is unsupported), or an explicit () to "
         "declare nothing is donatable"),
]}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def __str__(self):
        r = RULES[self.rule]
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{r.name}] {self.message} — fix: {r.fixit}")


_SUPPRESS_LINE = re.compile(r"#\s*fluidlint:\s*disable=([A-Za-z0-9,\s]+)")
_SUPPRESS_FILE = re.compile(r"#\s*fluidlint:\s*disable-file=([A-Za-z0-9,\s]+)")

# numpy scalar ops whose results are STRONG np.float64 (unlike math.*,
# whose Python floats stay weak and never promote a jax array)
_NP_FLOAT_OPS = {"sqrt", "exp", "expm1", "log", "log2", "log10", "log1p",
                 "power", "float_power", "sin", "cos", "tan", "tanh",
                 "sinh", "cosh", "arctan2", "hypot", "reciprocal"}

# float-producing factories and the position of their optional dtype arg
_FLOAT_FACTORIES = {"zeros": 1, "ones": 1, "full": 2, "linspace": 5,
                    "eye": 3, "empty": 1}

# trailing attribute paths (under a jax alias, or bare `from jax import X`)
# mapped to the positional indices that hold traced *functions* (the other
# positions are data: scan's carry, cond's operands, ...)
_TRACE_TAILS = {("jit",): (0,), ("vmap",): (0,), ("grad",): (0,),
                ("value_and_grad",): (0,), ("checkpoint",): (0,),
                ("lax", "scan"): (0,), ("lax", "fori_loop"): (2,),
                ("lax", "while_loop"): (0, 1), ("lax", "cond"): (1, 2)}

_HOST_SYNC_NP = {"asarray", "array", "copy"}


def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """Attribute/Name chain -> ('jax', 'numpy', 'sqrt'), or None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


class _ModuleContext:
    """Per-module alias table + the statically-visible traced-function set."""

    def __init__(self, tree: ast.Module):
        self.jnp_aliases: Set[str] = set()
        self.np_aliases: Set[str] = set()
        self.jax_aliases: Set[str] = set()
        self.imports_jax = False
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    tgt = a.asname or a.name.split(".")[0]
                    if a.name == "jax.numpy":
                        self.jnp_aliases.add(a.asname or "jax.numpy")
                        self.imports_jax = True
                    elif a.name.split(".")[0] == "jax":
                        self.jax_aliases.add(tgt)
                        self.imports_jax = True
                    elif a.name == "numpy":
                        self.np_aliases.add(tgt)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "jax":
                    self.imports_jax = True
                    for a in node.names:
                        if a.name == "numpy":
                            self.jnp_aliases.add(a.asname or "numpy")
                elif node.module and node.module.split(".")[0] == "jax":
                    self.imports_jax = True
        self.traced: Set[str] = self._collect_traced(tree)

    def _is_jit_expr(self, node: ast.AST) -> bool:
        """jax.jit / jit, possibly through functools.partial(jax.jit, ...)."""
        chain = _dotted(node)
        if chain is not None:
            return (self._is_jax_chain(chain, ("jit",))
                    or chain == ("jit",))
        if isinstance(node, ast.Call):
            fchain = _dotted(node.func)
            if fchain and fchain[-1] == "partial" and node.args:
                return self._is_jit_expr(node.args[0])
        return False

    def _is_jax_chain(self, chain: Tuple[str, ...],
                      tail: Tuple[str, ...]) -> bool:
        return (len(chain) >= len(tail) + 1
                and chain[0] in self.jax_aliases
                and chain[-len(tail):] == tail)

    def is_jnp_call(self, call: ast.Call) -> bool:
        chain = _dotted(call.func)
        if not chain or len(chain) < 2:
            return False
        head = ".".join(chain[:-1])
        return (chain[0] in self.jnp_aliases or head in self.jnp_aliases
                or (len(chain) >= 3 and chain[0] in self.jax_aliases
                    and chain[1] == "numpy"))

    def _collect_traced(self, tree: ast.Module) -> Set[str]:
        """Function names that are statically visibly jit/trace-entered."""
        traced: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(self._is_jit_expr(d) for d in node.decorator_list):
                    traced.add(node.name)
            elif isinstance(node, ast.Call):
                chain = _dotted(node.func)
                if chain is None:
                    continue
                fn_pos = _TRACE_TAILS.get(chain)
                if fn_pos is None and chain[0] in self.jax_aliases:
                    fn_pos = _TRACE_TAILS.get(chain[1:])
                if fn_pos is None:
                    continue
                for i in fn_pos:
                    if i < len(node.args) and isinstance(node.args[i],
                                                         ast.Name):
                        traced.add(node.args[i].id)
        return traced


class _Visitor(ast.NodeVisitor):
    def __init__(self, ctx: _ModuleContext, path: str):
        self.ctx = ctx
        self.path = path
        self.findings: List[Finding] = []
        self._loop_depth = 0
        self._traced_depth = 0

    def _flag(self, rule: str, node: ast.AST, message: str):
        self.findings.append(Finding(rule, self.path, node.lineno,
                                     node.col_offset, message))

    # ------------------------------------------------------------ FLD101
    def _check_test(self, node):
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.Call) and self.ctx.is_jnp_call(sub):
                chain = _dotted(sub.func)
                self._flag("FLD101", node,
                           f"branch condition calls "
                           f"{'.'.join(chain)} — a traced array, not a "
                           f"Python bool")
                return

    def visit_If(self, node):
        self._check_test(node)
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_test(node)
        self._loop(node)

    # ------------------------------------------------------- loops / defs
    def _loop(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_For(self, node):
        self._loop(node)

    def visit_FunctionDef(self, node):
        entering = (node.name in self.ctx.traced
                    or any(self.ctx._is_jit_expr(d)
                           for d in node.decorator_list))
        # a def starts a fresh loop scope: a loop *around* a def does not
        # unroll the def's body
        saved_loops = self._loop_depth
        self._loop_depth = 0
        if entering:
            self._traced_depth += 1
        self.generic_visit(node)
        if entering:
            self._traced_depth -= 1
        self._loop_depth = saved_loops

    visit_AsyncFunctionDef = visit_FunctionDef

    # ------------------------------------------------------------ FLD106
    def visit_ClassDef(self, node):
        is_policy = any((_dotted(b) or ("",))[-1] == "BasePolicy"
                        for b in node.bases)
        if is_policy and node.name != "BasePolicy":
            registered = False
            for d in node.decorator_list:
                tgt = d.func if isinstance(d, ast.Call) else d
                if (_dotted(tgt) or ("",))[-1] == "register_policy":
                    registered = True
            if not registered:
                self._flag("FLD106", node,
                           f"policy class {node.name} subclasses BasePolicy "
                           f"but is not @register_policy'd")
        self.generic_visit(node)

    # ------------------------------------------------------------- calls
    def visit_Call(self, node):
        chain = _dotted(node.func)
        if self.ctx.is_jnp_call(node):
            self._jnp_call(node, chain)
        elif chain:
            self._other_call(node, chain)
        self.generic_visit(node)

    def _jnp_call(self, node: ast.Call, chain):
        fn = chain[-1]
        if self._loop_depth > 0 and self._traced_depth > 0:
            self._flag("FLD102", node,
                       f"{'.'.join(chain)} inside a Python loop in a "
                       f"jit-traced function — the loop unrolls into the "
                       f"jaxpr")
        if fn in _FLOAT_FACTORIES:
            dtype_pos = _FLOAT_FACTORIES[fn]
            has_dtype = (any(k.arg == "dtype" for k in node.keywords)
                         or len(node.args) > dtype_pos)
            if not has_dtype:
                self._flag("FLD104", node,
                           f"jnp.{fn}(...) without dtype — float64 under "
                           f"x64, float32 otherwise; never the config's "
                           f"param_dtype")

    def _other_call(self, node: ast.Call, chain):
        head, fn = chain[0], chain[-1]
        np_call = head in self.ctx.np_aliases and len(chain) == 2
        if np_call and self.ctx.imports_jax and fn in _NP_FLOAT_OPS:
            self._flag("FLD103", node,
                       f"np.{fn}() returns a strong np.float64 scalar that "
                       f"upcasts any jax array it meets under x64")
        if self._traced_depth > 0:
            if np_call and fn in _HOST_SYNC_NP:
                self._flag("FLD105", node,
                           f"np.{fn}() inside a jit-traced function")
            elif (self.ctx._is_jax_chain(chain, ("device_get",))):
                self._flag("FLD105", node,
                           "jax.device_get inside a jit-traced function")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "item" and not node.args):
                self._flag("FLD105", node,
                           ".item() inside a jit-traced function")
        if self.ctx._is_jit_expr(node.func) and node.args:
            self._check_donate(node)

    # ------------------------------------------------------------ FLD107
    _STEPISH = re.compile(r"(^|_)(step|prefill|decode|insert)(_|$)|"
                          r"^make_\w*step$")

    def _check_donate(self, node: ast.Call):
        if any(k.arg in ("donate_argnums", "donate_argnames")
               for k in node.keywords):
            return
        target = node.args[0]
        name = None
        if isinstance(target, ast.Call):
            tchain = _dotted(target.func)
            name = tchain[-1] if tchain else None
        elif isinstance(target, ast.Name):
            name = target.id
        if name and self._STEPISH.search(name):
            self._flag("FLD107", node,
                       f"jax.jit({name}) without a donation declaration")


def _suppressions(text: str):
    """(file-level rule set, {lineno: rule set}); 'all' suppresses any."""
    file_rules: Set[str] = set()
    line_rules: Dict[int, Set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_FILE.search(line)
        if m and i <= 10:
            file_rules |= {r.strip().upper() for r in m.group(1).split(",")}
        m = _SUPPRESS_LINE.search(line)
        if m:
            line_rules[i] = {r.strip().upper() for r in m.group(1).split(",")}
    return file_rules, line_rules


def lint_source(text: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text. Returns unsuppressed findings."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("FLD101", path, e.lineno or 0, 0,
                        f"syntax error: {e.msg}")]
    ctx = _ModuleContext(tree)
    v = _Visitor(ctx, path)
    v.visit(tree)
    file_rules, line_rules = _suppressions(text)
    out = []
    for f in v.findings:
        sup = file_rules | line_rules.get(f.line, set())
        if "ALL" in sup or f.rule in sup:
            continue
        out.append(f)
    return out


def iter_py_files(paths: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        pth = Path(p)
        if pth.is_dir():
            files.extend(sorted(f for f in pth.rglob("*.py")
                                if "__pycache__" not in f.parts))
        elif pth.suffix == ".py":
            files.append(pth)
    return files


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    out: List[Finding] = []
    for f in iter_py_files(paths):
        out.extend(lint_source(f.read_text(), str(f)))
    return out
