"""Static analysis for the FLuID reproduction (DESIGN.md §11).

Three passes, one CLI (``python -m repro.analysis``), one CI gate:

  * ``lint``             — AST rules over ``src/`` catching the JAX footguns
                           this codebase has actually hit: tracer-unsafe
                           control flow, trace-time loop unrolling, implicit
                           float64 promotion, host syncs under jit,
                           unregistered dropout policies, and step functions
                           jitted without a donation declaration.
  * ``contracts``        — trace-time checks: every workload's loss/step
                           traces free of f64 and host callbacks, the fleet /
                           serving / masked-train programs compile exactly
                           once across mixed masks and hyperparameters, and
                           dropped-block dW cotangents are structurally zero
                           (NaN-poison proof) for every 128-aligned configs/
                           shape.
  * ``kernel_contracts`` — whole-zoo static sweep of the Pallas kernel
                           alignment grammar (DESIGN.md §10): tile
                           divisibility, mask shapes, unit-spec tile
                           expansion (including unit-major ``tile < 0``).

Each pass returns plain finding lists so tests can assert on them; the CLI
aggregates exit status. Suppress lint findings with
``# fluidlint: disable=RULE`` (see analysis/lint.py).
"""
from repro.analysis.lint import RULES, Finding, lint_paths, lint_source  # noqa: F401
