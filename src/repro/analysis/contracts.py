"""Trace-time contract checker (pass 2 of repro.analysis).

Where lint.py reads source text, this pass traces the real programs —
``jax.make_jaxpr`` / ``jax.eval_shape`` / actual jit calls — and asserts the
invariants the repo's performance story depends on:

  * **no-f64**: traced under ``jax.experimental.enable_x64()`` (which makes
    every implicit float64 promotion visible as an f64 outvar instead of
    being silently truncated to f32), the train step of every zoo arch, the
    paper-scale model grads, and all three optimizers produce no float64
    values. The same jaxpr walk rejects host-callback primitives — nothing
    in a hot path may sync back to Python.
  * **single-trace**: "the mask is data, not shape" (DESIGN.md §8). The
    masked train step, the fleet cohort program, and the ServeEngine's three
    compiled bodies must each trace exactly once across different mask-bank
    contents and mixed per-client (lr, n_steps) hyperparameters. Measured
    with ``jax.jit``'s ``_cache_size`` and ServeEngine.trace_counts, not
    inferred.
  * **dropped-dW-zero**: the structural guarantee of DESIGN.md §10. Dropped
    weight tiles are poisoned with NaN; the forward must stay finite and the
    dropped blocks'/heads' weight cotangents must come back bitwise zero —
    proof the kernels never read or write those tiles, for every distinct
    128-aligned FFN width and head count in configs/.

Init functions are NOT traced under x64: ``jax.random.normal`` defaults to
f64 there by design and every init astypes to the param dtype immediately;
the static factory-dtype rule (lint FLD104) covers init-time discipline.

Checks return lists of :class:`Violation`; ``run_contracts()`` runs the
whole registry (unexpected exceptions become violations, not crashes).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

F64 = np.dtype("float64")

# host-sync primitives that must not appear in any hot-path jaxpr
CALLBACK_PRIMITIVES = {"pure_callback", "io_callback", "callback",
                       "debug_callback", "python_callback"}


@dataclass
class Violation:
    check: str          # registry key, e.g. "no-f64-zoo"
    where: str          # traced entity, e.g. "train_step[stablelm-12b]"
    message: str

    def __str__(self):
        return f"{self.check}: {self.where}: {self.message}"


# ---------------------------------------------------------------------------
# jaxpr walking

def _iter_subjaxprs(params: dict):
    def as_jaxpr(v):
        if hasattr(v, "eqns"):
            return v                            # raw Jaxpr
        if hasattr(v, "jaxpr"):
            return v.jaxpr                      # ClosedJaxpr
        return None

    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for w in vs:
            jx = as_jaxpr(w)
            if jx is not None:
                yield jx


def walk_jaxpr(jaxpr) -> Dict[str, List[str]]:
    """Collect f64-producing equations and callback primitives, recursing
    into every sub-jaxpr carried in eqn params (scan/cond/jit bodies)."""
    hits = {"f64": [], "callback": []}

    def visit(jx):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in CALLBACK_PRIMITIVES:
                hits["callback"].append(name)
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is not None and dt == F64:
                    hits["f64"].append(f"{name} -> {aval.str_short()}")
            for sub in _iter_subjaxprs(eqn.params):
                visit(sub)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return hits


def _trace_violations(check: str, where: str, fn, *args) -> List[Violation]:
    """Trace fn under x64 and convert walk hits into Violations."""
    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(fn)(*args)
    hits = walk_jaxpr(jaxpr)
    out = []
    for h in hits["f64"][:5]:
        out.append(Violation(check, where,
                             f"float64 value in traced program: {h}"))
    if len(hits["f64"]) > 5:
        out.append(Violation(check, where,
                             f"... {len(hits['f64']) - 5} more f64 values"))
    for h in sorted(set(hits["callback"])):
        out.append(Violation(check, where,
                             f"host callback primitive '{h}' under jit"))
    return out


# ---------------------------------------------------------------------------
# input spec helpers

def _zoo_batch_spec(cfg, batch=2, seq=8):
    s = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.is_encdec:
        s["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                           jnp.dtype(cfg.dtype))
    return s


def _model_batch(model_cls, batch=2):
    """Concrete (x, y) for a paper-scale model; LSTM takes int tokens."""
    if model_cls.__name__ == "ShakespeareLSTM":
        x = jnp.zeros((batch, model_cls.seq_len), jnp.int32)
    else:
        x = jnp.zeros((batch, *model_cls.input_shape), jnp.float32)
    y = jnp.zeros((batch,), jnp.int32)
    return x, y


# ---------------------------------------------------------------------------
# no-f64 / no-callback checks

def check_zoo_train_no_f64() -> List[Violation]:
    """Trace make_train_step for every configs/ arch under x64."""
    from repro.configs.base import all_configs
    from repro.launch.steps import make_train_step
    from repro.models import model as model_lib
    from repro.optim import make_optimizer
    out = []
    for arch, cfg in all_configs().items():
        cfg = cfg.smoke().with_overrides(grad_accum=1)
        params = jax.eval_shape(
            functools.partial(model_lib.init_params, cfg),
            jax.random.PRNGKey(0))
        opt_state = jax.eval_shape(make_optimizer(cfg.optimizer).init, params)
        step = make_train_step(cfg)
        out += _trace_violations("no-f64-zoo", f"train_step[{arch}]",
                                 step, params, opt_state,
                                 _zoo_batch_spec(cfg))
    return out


def check_models_no_f64() -> List[Violation]:
    """Trace grads of the paper-scale + kernel fleet models under x64."""
    from repro.fl.client import make_weighted_loss
    from repro.models.kernel_models import KERNEL_MODELS
    from repro.models.small import MODELS
    out = []
    for name, cls in {**MODELS, **KERNEL_MODELS}.items():
        x, y = _model_batch(cls)
        v = jnp.ones(y.shape, jnp.float32)
        loss = make_weighted_loss(cls)
        out += _trace_violations("no-f64-models", f"grad[{name}]",
                                 jax.grad(loss),
                                 jax.eval_shape(cls.init,
                                                jax.random.PRNGKey(0)),
                                 x, y, v)
    return out


def check_optim_no_f64() -> List[Violation]:
    """Trace every optimizer's update under x64 on a small f32 tree."""
    from repro.optim import make_optimizer
    params = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
              "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    out = []
    for name in ("sgd", "sgdm", "adamw"):
        opt = make_optimizer(name)
        state = jax.eval_shape(opt.init, params)
        out += _trace_violations(
            "no-f64-optim", f"update[{name}]",
            lambda g, s, p: opt.update(g, s, p, 0.01), params, state, params)
    return out


# ---------------------------------------------------------------------------
# single-trace checks

def check_train_step_single_trace(arch="stablelm-12b") -> List[Violation]:
    """The masked train step compiles once across mask contents."""
    from repro.configs.base import get_config
    from repro.core import transformer_hooks as hooks
    from repro.launch.serving import rate_masks
    from repro.launch.steps import make_train_step
    from repro.models import model as model_lib
    from repro.optim import make_optimizer
    cfg = get_config(arch).smoke().with_overrides(grad_accum=1)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    # the probe reuses params/opt_state across calls, so nothing is donatable
    step = jax.jit(make_train_step(cfg, with_masks=True))  # fluidlint: disable=FLD107
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 64, (2, 9))[:, :-1],
                                   dtype=jnp.int32),
             "targets": jnp.asarray(rng.randint(0, 64, (2, 9))[:, 1:],
                                    dtype=jnp.int32)}
    for masks in (hooks.full_masks(cfg), rate_masks(cfg, 0.5),
                  rate_masks(cfg, 0.75, policy="random")):
        params, opt_state, _ = step(params, opt_state, batch, masks)
    n = step._cache_size()
    if n != 1:
        return [Violation("single-trace-train",
                          f"make_train_step[{arch}, with_masks]",
                          f"{n} traces across 3 mask contents (want 1): "
                          f"a mask shape or dtype is leaking into the "
                          f"program structure")]
    return []


def check_fleet_single_trace() -> List[Violation]:
    """One cohort program across rounds with different mask-bank contents
    and mixed per-client (lr, n_steps) hyperparameters."""
    from repro.fl.client import FleetClient
    from repro.fl.fleet import FleetEngine
    from repro.models.small import FemnistCNN
    rng = np.random.RandomState(0)
    clients = [
        FleetClient(id=i, model_cls=FemnistCNN,
                    x=rng.randn(n, 28, 28, 1).astype(np.float32),
                    y=rng.randint(0, 62, (n,)).astype(np.int32),
                    speed=1.0, batch_size=20, local_epochs=1,
                    lr=0.01, seed=0)
        for i, n in enumerate((60, 40, 60, 40))]
    engine = FleetEngine(FemnistCNN, clients, FemnistCNN.UNIT_SPECS)
    params = FemnistCNN.init(jax.random.PRNGKey(0))
    before = engine._run._cache_size()

    def km(c1, c2, f1):
        return {"conv1": np.arange(c1), "conv2": np.arange(c2),
                "fc1": np.arange(f1)}

    # The bank's ROW COUNT is shape (it only changes on calibration steps);
    # mask CONTENTS, row assignment, and hyperparameters are data. Hold the
    # number of distinct masks at 2 across both rounds and vary everything
    # else — the cohort program must not re-specialize.
    # round 1: two stragglers, uniform hyperparameters
    engine.run_cohort(params, {0: km(12, 48, 90), 1: km(8, 32, 60)},
                      rates={0: 0.75, 1: 0.5})
    # round 2: different mask contents + mixed lr and per-client step counts
    engine.run_cohort(params, {0: km(10, 40, 80), 2: km(14, 56, 100)},
                      rates={0: 0.6, 2: 0.9},
                      lr=np.array([0.01, 0.02, 0.005, 0.01], np.float32),
                      n_steps=np.array([1, 2, 1, 2], np.int32))
    delta = engine._run._cache_size() - before
    if delta != 1:
        return [Violation("single-trace-fleet", "FleetEngine.run_cohort",
                          f"{delta} traces across 2 heterogeneous rounds "
                          f"(want 1): masks or hyperparameters are "
                          f"re-specializing the cohort program")]
    return []


def check_serve_single_trace(arch="stablelm-12b") -> List[Violation]:
    """ServeEngine's prefill/insert/decode each trace once over a queue of
    mixed dropout rates, prompt lengths, and generation lengths."""
    from repro.configs.base import get_config
    from repro.launch.serving import ServeEngine, ServeRequest, rate_masks
    from repro.models import model as model_lib
    cfg = get_config(arch).smoke()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_prompt_len=8,
                      max_gen_len=4, chunk=2, bank_size=4)
    rng = np.random.RandomState(0)

    def prompt(n):
        return rng.randint(0, 64, (n,)).astype(np.int32)

    eng.submit(ServeRequest(tokens=prompt(8), gen_len=4, masks=None))
    eng.submit(ServeRequest(tokens=prompt(5), gen_len=3,
                            masks=rate_masks(cfg, 0.5)))
    eng.submit(ServeRequest(tokens=prompt(7), gen_len=4,
                            masks=rate_masks(cfg, 0.75, policy="random")))
    eng.run()
    out = []
    for k, n in eng.trace_counts.items():
        if n != 1:
            out.append(Violation(
                "single-trace-serve", f"ServeEngine.{k}[{arch}]",
                f"traced {n} times over a mixed-rate queue (want 1)"))
    return out


def check_population_single_trace() -> List[Violation]:
    """The sharded cohort program compiles once across population rounds:
    each round samples a DIFFERENT cohort from the store, but shard shapes
    are constant (equal-size partitions), so the (S, Cs, ...) program must
    never re-trace. Uses policy='none' to hold the mask-bank row count at
    1 — bank rows are legitimately shape and change only on calibration."""
    from repro.fl import shard_fleet
    from repro.fl.population import PopulationConfig, build_population
    cfg = PopulationConfig(n_clients=512, cohort_size=4, workload="synth",
                           backend="sharded_fleet", n_shards=2,
                           policy="none", n_partitions=8,
                           samples_per_partition=20, seed=0)
    sim = build_population(cfg)
    before = set(shard_fleet._SHARDED_CACHE)
    # Round 0 feeds host-resident init params; round 1+ params carry the
    # program's replicated NamedSharding, which legitimately costs one
    # extra compile. Steady state starts at round 1: from there the cache
    # must not grow, whatever cohort gets sampled.
    sim.run(2)
    new = [k for k in shard_fleet._SHARDED_CACHE if k not in before]
    if len(new) != 1:
        return [Violation("single-trace-population",
                          "ShardedFleetEngine program cache",
                          f"{len(new)} sharded programs built for one "
                          f"(model, mesh, S) (want 1)")]
    fn = shard_fleet._SHARDED_CACHE[new[0]]
    n0 = fn._cache_size()
    sim.run(2)                       # two more rounds, two more cohorts
    n = fn._cache_size()
    if not (n0 <= 2 and n == n0):
        return [Violation(
            "single-trace-population", "PopulationSim.run_round",
            f"sharded cohort program traced {n} times across 4 rounds "
            f"(want <= 2: init + steady state): a sampled id or shard "
            f"assignment is leaking into program structure")]
    return []


def check_population_no_host_sync() -> List[Violation]:
    """Device side of the population round loop, traced under x64: cohort
    sampling, the sharded cohort program, hierarchical combine, and the
    store scatter-update contain no f64 and no host callbacks. Straggler
    calibration (core/straggler.plan_from_store) is deliberately host-side
    numpy — it runs once per round on O(cohort) scalars OUTSIDE any traced
    program, and is therefore out of scope here by design."""
    from repro.core.aggregate import combine_partials
    from repro.fl.population import (ClientStore, _sample_cohort,
                                     _update_from_round)
    from repro.fl.shard_fleet import _sharded_cohort_fn
    from repro.launch.mesh import make_host_mesh
    from repro.models.small import SynthMLP

    out = []
    store = ClientStore.empty(64).register(
        np.arange(64), np.full(64, 10.0, np.float32),
        np.zeros(64, np.int32))
    out += _trace_violations(
        "population-no-host-sync", "ClientStore.sample_cohort",
        functools.partial(_sample_cohort, size=8), store.active,
        jax.random.PRNGKey(0))
    ids = jnp.arange(8, dtype=jnp.int32)
    out += _trace_violations(
        "population-no-host-sync", "ClientStore.update_from_round",
        _update_from_round, store, ids,
        jnp.full((8,), 10.0, jnp.float32), jnp.ones((8,), jnp.float32))

    # sharded cohort program + combine, S=2 shards of 2 clients on 1 device
    mesh = make_host_mesh(data=1)
    run = _sharded_cohort_fn(SynthMLP, mesh, 2, False, True)
    params = SynthMLP.init(jax.random.PRNGKey(0))
    bank = jax.tree.map(lambda p: p[None].astype(jnp.float32) * 0 + 1,
                        params)
    S, Cs, steps, bs = 2, 2, 1, 20
    xs = jnp.zeros((S, Cs, steps, bs, 32), jnp.float32)
    ys = jnp.zeros((S, Cs, steps, bs), jnp.int32)
    sw = jnp.ones((S, Cs, steps, bs), jnp.float32)
    mi = jnp.zeros((S, Cs), jnp.int32)
    lrs = jnp.full((S, Cs), 0.05, jnp.float32)
    w = jnp.full((S, Cs), float(bs), jnp.float32)
    out += _trace_violations(
        "population-no-host-sync", "sharded_cohort_program",
        functools.partial(run, n_steps=steps),
        params, bank, mi, xs, ys, sw, lrs, w)
    num = jax.tree.map(jnp.zeros_like, params)
    out += _trace_violations(
        "population-no-host-sync", "combine_partials",
        combine_partials, params, num, jnp.ones((1,), jnp.float32), bank)
    return out


def check_async_single_trace() -> List[Violation]:
    """The async server step compiles once at steady state: every dispatch
    group is capacity-padded to buffer_k clients (one cohort-program
    shape), every drained buffer is exactly buffer_k arrivals with a
    rebuilt mask bank of constant row count (policy='none' holds it at 1,
    as in the population check — bank rows are legitimately shape and move
    only on calibration), so neither the dispatch program nor
    `aggregate_buffered` may retrace per buffer, whatever arrival order
    the virtual clock produces. Round 0 feeds host-resident init params;
    steady state starts once params carry device sharding — budget <= 2
    traces for the init transition, then the caches must freeze."""
    from repro.core.aggregate import aggregate_buffered
    from repro.fl import fleet
    from repro.fl.async_rounds import AsyncConfig
    from repro.fl.population import PopulationConfig, build_population
    from repro.core.straggler import ArrivalModel

    cfg = PopulationConfig(
        n_clients=512, cohort_size=4, workload="synth", backend="async",
        policy="none", n_partitions=8, samples_per_partition=20,
        async_cfg=AsyncConfig(buffer_k=4, concurrency=8,
                              arrival=ArrivalModel(tail_sigma=0.5, seed=0)),
        seed=0)
    sim = build_population(cfg)
    before = set(fleet._COHORT_CACHE)
    agg0 = aggregate_buffered._cache_size()
    sim.run(2)
    new = [k for k in fleet._COHORT_CACHE if k not in before]
    progs = [fleet._COHORT_CACHE[k] for k in new] or [
        fleet._COHORT_CACHE[("SynthMLP", False, True)]]
    n0 = [p._cache_size() for p in progs]
    agg1 = aggregate_buffered._cache_size()
    sim.run(3)                  # more buffers, different arrival orders
    out = []
    n1 = [p._cache_size() for p in progs]
    agg2 = aggregate_buffered._cache_size()
    if n1 != n0:
        out.append(Violation(
            "single-trace-async", "async dispatch program",
            f"cohort program retraced at steady state ({n0} -> {n1}): a "
            f"dispatch-group shape is leaking arrival structure"))
    if not (agg1 - agg0 <= 2 and agg2 == agg1):
        out.append(Violation(
            "single-trace-async", "aggregate_buffered",
            f"buffer aggregation traced {agg1 - agg0} times in 2 rounds / "
            f"{agg2 - agg1} more in 3 rounds (want <= 2 then 0): buffer "
            f"composition is leaking into program shape"))
    return out


# ---------------------------------------------------------------------------
# dropped-dW-zero checks (NaN poison)

def _ffn_cases():
    """Unique (F, ffn_kind) over all configs/ FFN widths, incl. MoE expert
    width; kernel fleet models ride along with their gelu FFNs."""
    from repro.configs.base import all_configs
    cases = {}
    for arch, cfg in all_configs().items():
        for F in filter(None, (cfg.d_ff, cfg.moe_ff)):
            cases.setdefault((F, cfg.ffn_kind), arch)
    cases.setdefault((1024, "gelu"), "kernel_mlp")
    cases.setdefault((256, "gelu"), "kernel_attn")
    return cases


def check_dropped_dw_zero_ffn() -> List[Violation]:
    """For every distinct FFN width in the zoo: poison dropped 128-blocks
    with NaN, demand a finite forward and bitwise-zero dropped dW."""
    from repro.kernels.masked_ffn import BLOCK_NEURONS, masked_ffn
    from repro.models.layers import _KERNEL_ACT
    out = []
    d, M = 16, 8
    for (F, kind), arch in sorted(_ffn_cases().items()):
        where = f"masked_ffn[F={F}, {kind}] ({arch})"
        if F % BLOCK_NEURONS != 0:
            # kernel-ineligible width: the contract is a loud ValueError,
            # never a silent dense fallback (kernel_contracts re-checks)
            try:
                jax.eval_shape(functools.partial(masked_ffn, act="silu",
                                                 interpret=True),
                               jax.ShapeDtypeStruct((M, d), jnp.float32),
                               jax.ShapeDtypeStruct((d, F), jnp.float32),
                               jax.ShapeDtypeStruct((F, d), jnp.float32),
                               jax.ShapeDtypeStruct((F // BLOCK_NEURONS,),
                                                    jnp.float32))
            except ValueError:
                continue
            out.append(Violation("dw-zero-ffn", where,
                                 f"F={F} is not 128-aligned but masked_ffn "
                                 f"accepted it silently"))
            continue
        act, gated = _KERNEL_ACT[kind]
        nb = F // BLOCK_NEURONS
        block_mask = np.ones((nb,), np.float32)
        block_mask[1::2] = 0.0                       # drop every other block
        dropped = np.repeat(block_mask == 0, BLOCK_NEURONS)
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(M, d).astype(np.float32))
        w_in = rng.randn(d, F).astype(np.float32)
        w_out = rng.randn(F, d).astype(np.float32)
        w_in[:, dropped] = np.nan                    # poison dropped tiles
        w_out[dropped, :] = np.nan
        w_gate = None
        if gated:
            w_gate = rng.randn(d, F).astype(np.float32)
            w_gate[:, dropped] = np.nan

        def loss(wi, wo, wg):
            return jnp.sum(masked_ffn(x, wi, wo, jnp.asarray(block_mask),
                                      wg, act=act, interpret=True))
        y = masked_ffn(x, jnp.asarray(w_in), jnp.asarray(w_out),
                       jnp.asarray(block_mask),
                       None if w_gate is None else jnp.asarray(w_gate),
                       act=act, interpret=True)
        if not np.isfinite(np.asarray(y)).all():
            out.append(Violation("dw-zero-ffn", where,
                                 "forward read a dropped (NaN-poisoned) "
                                 "weight tile"))
            continue
        grads = jax.grad(loss, argnums=(0, 1) + ((2,) if gated else ()))(
            jnp.asarray(w_in), jnp.asarray(w_out),
            None if w_gate is None else jnp.asarray(w_gate))
        named = [("dW_in", np.asarray(grads[0])[:, dropped]),
                 ("dW_out", np.asarray(grads[1])[dropped, :])]
        if gated:
            named.append(("dW_gate", np.asarray(grads[2])[:, dropped]))
        for gname, tile in named:
            if not (tile == 0.0).all():
                out.append(Violation(
                    "dw-zero-ffn", where,
                    f"{gname} of dropped blocks is not bitwise zero — the "
                    f"backward kernel touched a dropped tile"))
    return out


def check_dropped_dw_zero_attn() -> List[Violation]:
    """For every distinct head count in the zoo: poison dropped head slabs
    with NaN, demand a finite forward and bitwise-zero dropped dW."""
    from repro.kernels.masked_attn import masked_attention
    from repro.configs.base import all_configs
    heads = sorted({cfg.n_heads for cfg in all_configs().values()} | {4})
    out = []
    B, S, d, hd = 1, 4, 16, 8
    for H in heads:
        where = f"masked_attention[H={H}]"
        mask = np.ones((H,), np.float32)
        mask[1::2] = 0.0
        dropped = np.repeat(mask == 0, hd)
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(B, S, d).astype(np.float32))
        ws = {}
        for name in ("wq", "wk", "wv"):
            w = rng.randn(d, H * hd).astype(np.float32)
            w[:, dropped] = np.nan
            ws[name] = jnp.asarray(w)
        wo = rng.randn(H * hd, d).astype(np.float32)
        wo[dropped, :] = np.nan
        ws["wo"] = jnp.asarray(wo)

        def loss(wq, wk, wv, wo_):
            return jnp.sum(masked_attention(x, wq, wk, wv, wo_,
                                            jnp.asarray(mask), n_heads=H,
                                            block_m=8, interpret=True))
        y = masked_attention(x, ws["wq"], ws["wk"], ws["wv"], ws["wo"],
                             jnp.asarray(mask), n_heads=H, block_m=8,
                             interpret=True)
        if not np.isfinite(np.asarray(y)).all():
            out.append(Violation("dw-zero-attn", where,
                                 "forward read a dropped (NaN-poisoned) "
                                 "head slab"))
            continue
        g = jax.grad(loss, argnums=(0, 1, 2, 3))(
            ws["wq"], ws["wk"], ws["wv"], ws["wo"])
        named = [("dWq", np.asarray(g[0])[:, dropped]),
                 ("dWk", np.asarray(g[1])[:, dropped]),
                 ("dWv", np.asarray(g[2])[:, dropped]),
                 ("dWo", np.asarray(g[3])[dropped, :])]
        for gname, tile in named:
            if not (tile == 0.0).all():
                out.append(Violation(
                    "dw-zero-attn", where,
                    f"{gname} of dropped heads is not bitwise zero — the "
                    f"backward kernel touched a dropped head slab"))
    return out


# ---------------------------------------------------------------------------
# registry / driver

CHECKS: Dict[str, Callable[[], List[Violation]]] = {
    "no-f64-zoo": check_zoo_train_no_f64,
    "no-f64-models": check_models_no_f64,
    "no-f64-optim": check_optim_no_f64,
    "single-trace-train": check_train_step_single_trace,
    "single-trace-fleet": check_fleet_single_trace,
    "single-trace-serve": check_serve_single_trace,
    "single-trace-population": check_population_single_trace,
    "single-trace-async": check_async_single_trace,
    "population-no-host-sync": check_population_no_host_sync,
    "dw-zero-ffn": check_dropped_dw_zero_ffn,
    "dw-zero-attn": check_dropped_dw_zero_attn,
}


def run_contracts(progress=None, only=None) -> List[Violation]:
    """Run trace-time contracts; `only` narrows to a list of CHECKS names
    (unknown names are a loud error, not an empty green run)."""
    checks = CHECKS
    if only:
        unknown = [n for n in only if n not in CHECKS]
        if unknown:
            raise KeyError(f"unknown contract(s) {unknown}; "
                           f"available: {sorted(CHECKS)}")
        checks = {n: CHECKS[n] for n in only}
    out = []
    for name, fn in checks.items():
        if progress:
            progress(name)
        try:
            out.extend(fn())
        except Exception as e:                       # noqa: BLE001
            out.append(Violation(name, fn.__name__,
                                 f"check crashed: {type(e).__name__}: {e}"))
    return out
