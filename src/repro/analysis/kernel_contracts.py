"""Static kernel-contract validator (pass 3 of repro.analysis).

Pure shape/grammar checking — no kernel executes. ``jax.eval_shape``
through the jit'd Pallas entry points runs each kernel's ``_validate`` at
trace time with zero allocation, so the whole zoo sweeps in milliseconds
at REAL dimensions (d_model in the thousands, d_ff in the tens of
thousands):

  * **tile eligibility**: every FFN width in configs/ (d_ff and the MoE
    expert width) is classified against the BLOCK_NEURONS=128 grammar.
    Aligned widths must trace through ``masked_ffn`` / ``masked_ffn_batch``;
    misaligned widths must raise ValueError — the loud-failure contract
    (never a silent dense fallback). Head layouts sweep the same way
    through ``masked_head_proj`` / ``masked_head_merge``.
  * **mask-shape rejection**: wrong block-mask lengths, wrong row-mask
    shapes, and non-dividing head masks must all raise ValueError.
  * **UNIT_SPECS grammar**: every (path, axis, tile) entry of every fleet
    model resolves against the model's eval_shape'd init tree, the axis
    length equals size * |tile|, and ``expand_indices`` is a permutation —
    with tile < 0 additionally unit-major (each unit owns |tile|
    contiguous slots, the attention-head layout).
  * **constants**: ops.BLOCK_NEURONS == masked_ffn.BLOCK_NEURONS, and
    ``neuron_mask_to_block_mask`` keeps a block iff any neuron survives.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import Violation

_F32 = jnp.float32


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, _F32)


def _traces_ok(fn, *specs):
    """(ok, err): eval_shape fn on specs; ValueError -> (False, msg)."""
    try:
        jax.eval_shape(fn, *specs)
        return True, ""
    except ValueError as e:
        return False, str(e)


# ---------------------------------------------------------------------------
# FFN width sweep

def _ffn_widths():
    """{(F, d_model): [arch, ...]} over d_ff and MoE expert widths."""
    from repro.configs.base import all_configs
    widths: Dict[tuple, list] = {}
    for arch, cfg in all_configs().items():
        for F in {cfg.d_ff, cfg.moe_ff}:
            widths.setdefault((F, cfg.d_model), []).append(arch)
    return widths


def check_ffn_tile_eligibility() -> List[Violation]:
    from repro.kernels.masked_ffn import (BLOCK_NEURONS, masked_ffn,
                                          masked_ffn_batch)
    out = []
    M = 8
    f_single = functools.partial(masked_ffn, act="silu", interpret=True)
    f_batch = functools.partial(masked_ffn_batch, act="silu", interpret=True)
    for (F, d), archs in sorted(_ffn_widths().items()):
        where = f"d_ff={F}, d_model={d} ({', '.join(sorted(archs))})"
        aligned = F % BLOCK_NEURONS == 0
        nb = max(F // BLOCK_NEURONS, 1)
        ok1, err1 = _traces_ok(f_single, _sds(M, d), _sds(d, F), _sds(F, d),
                               _sds(nb))
        ok2, err2 = _traces_ok(f_batch, _sds(M, d), _sds(d, F), _sds(F, d),
                               _sds(M, F))
        if aligned:
            if not ok1:
                out.append(Violation("kernel-ffn-tiles", where,
                                     f"128-aligned width rejected by "
                                     f"masked_ffn: {err1}"))
            if not ok2:
                out.append(Violation("kernel-ffn-tiles", where,
                                     f"128-aligned width rejected by "
                                     f"masked_ffn_batch: {err2}"))
        else:
            # kernel-ineligible width: models must keep the dense masked
            # path; the kernels must refuse loudly
            if ok1 or ok2:
                out.append(Violation(
                    "kernel-ffn-tiles", where,
                    f"width is NOT {BLOCK_NEURONS}-aligned but a masked-FFN "
                    f"kernel accepted it — the silent-dense footgun"))
    return out


def check_head_layouts() -> List[Violation]:
    """Every config's (n_heads, head_dim) projection layout traces through
    the head-masked kernels."""
    from repro.configs.base import all_configs
    from repro.kernels.masked_attn import masked_head_merge, masked_head_proj
    out = []
    M = 8
    seen = set()
    for arch, cfg in sorted(all_configs().items()):
        H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
        if (H, hd, d) in seen:
            continue
        seen.add((H, hd, d))
        where = f"H={H}, head_dim={hd}, d_model={d} ({arch})"
        okp, errp = _traces_ok(
            functools.partial(masked_head_proj, interpret=True),
            _sds(M, d), _sds(d, H * hd), _sds(H))
        okm, errm = _traces_ok(
            functools.partial(masked_head_merge, interpret=True),
            _sds(M, H * hd), _sds(H * hd, d), _sds(H))
        if not okp:
            out.append(Violation("kernel-head-layout", where,
                                 f"masked_head_proj rejected the layout: "
                                 f"{errp}"))
        if not okm:
            out.append(Violation("kernel-head-layout", where,
                                 f"masked_head_merge rejected the layout: "
                                 f"{errm}"))
    return out


def check_mask_shape_rejection() -> List[Violation]:
    """Malformed masks must raise ValueError at trace time, not compute."""
    from repro.kernels.masked_attn import masked_head_proj
    from repro.kernels.masked_ffn import masked_ffn, masked_ffn_batch
    out = []
    d, F, M = 16, 256, 8
    cases = [
        ("block_mask wrong length",
         functools.partial(masked_ffn, act="silu", interpret=True),
         (_sds(M, d), _sds(d, F), _sds(F, d), _sds(F // 128 + 1))),
        ("neuron-granular mask passed to the block-mask entry",
         functools.partial(masked_ffn, act="silu", interpret=True),
         (_sds(M, d), _sds(d, F), _sds(F, d), _sds(F))),
        ("row_mask wrong row count",
         functools.partial(masked_ffn_batch, act="silu", interpret=True),
         (_sds(M, d), _sds(d, F), _sds(F, d), _sds(M + 1, F))),
        ("misaligned hidden dim (F=200)",
         functools.partial(masked_ffn, act="silu", interpret=True),
         (_sds(M, d), _sds(d, 200), _sds(200, d), _sds(1))),
        ("head mask not dividing the projection (H=3 into 64)",
         functools.partial(masked_head_proj, interpret=True),
         (_sds(M, d), _sds(d, 64), _sds(3))),
    ]
    for label, fn, specs in cases:
        ok, _ = _traces_ok(fn, *specs)
        if ok:
            out.append(Violation("kernel-mask-shapes", label,
                                 "malformed mask was accepted silently "
                                 "(expected a trace-time ValueError)"))
    return out


# ---------------------------------------------------------------------------
# UNIT_SPECS grammar

def _get_path(tree, path):
    node = tree
    for part in path.split("/"):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check_unit_specs() -> List[Violation]:
    from repro.core.submodel import expand_indices
    from repro.models.kernel_models import KERNEL_MODELS
    from repro.models.small import MODELS
    out = []
    for name, cls in {**MODELS, **KERNEL_MODELS}.items():
        params = jax.eval_shape(cls.init, jax.random.PRNGKey(0))
        for g in cls.UNIT_SPECS:
            size = g["size"]
            for role in ("out", "in"):
                for path, axis, tile in g[role]:
                    where = f"{name}:{g['name']} ({role} {path} ax{axis})"
                    leaf = _get_path(params, path)
                    if leaf is None:
                        out.append(Violation(
                            "unit-specs", where,
                            f"path '{path}' not found in the init tree"))
                        continue
                    if not -leaf.ndim <= axis < leaf.ndim:
                        out.append(Violation(
                            "unit-specs", where,
                            f"axis {axis} out of range for shape "
                            f"{leaf.shape}"))
                        continue
                    t = abs(tile)
                    if leaf.shape[axis] != size * t:
                        out.append(Violation(
                            "unit-specs", where,
                            f"axis length {leaf.shape[axis]} != size*|tile| "
                            f"= {size}*{t}"))
                        continue
                    # full keep must expand to a permutation of the axis
                    full = expand_indices(np.arange(size), tile, size)
                    if not np.array_equal(np.sort(full),
                                          np.arange(size * t)):
                        out.append(Violation(
                            "unit-specs", where,
                            f"expand_indices(all, tile={tile}) is not a "
                            f"permutation of the axis"))
                        continue
                    if tile < 0:
                        # unit-major: each unit owns |tile| contiguous slots
                        # (the attention-head layout decode_gqa relies on)
                        for u in (0, size - 1):
                            got = expand_indices(np.array([u]), tile, size)
                            want = np.arange(u * t, (u + 1) * t)
                            if not np.array_equal(got, want):
                                out.append(Violation(
                                    "unit-specs", where,
                                    f"tile={tile} unit {u} expands to "
                                    f"{got[:4]}... (want the contiguous "
                                    f"slab {u * t}..{(u + 1) * t - 1})"))
                                break
    return out


# ---------------------------------------------------------------------------
# constants / round trips

def check_block_constants() -> List[Violation]:
    from repro.kernels import masked_ffn as mffn
    from repro.kernels import ops
    out = []
    if ops.BLOCK_NEURONS != mffn.BLOCK_NEURONS:
        out.append(Violation(
            "kernel-constants", "BLOCK_NEURONS",
            f"ops.BLOCK_NEURONS={ops.BLOCK_NEURONS} != "
            f"masked_ffn.BLOCK_NEURONS={mffn.BLOCK_NEURONS}"))
    rng = np.random.RandomState(0)
    F = 512
    neuron = (rng.rand(F) < 0.3).astype(np.float32)
    blocks = ops.neuron_mask_to_block_mask(neuron)
    want = (neuron.reshape(-1, ops.BLOCK_NEURONS).max(axis=1) > 0)
    if blocks.shape != (F // ops.BLOCK_NEURONS,) or not np.array_equal(
            blocks.astype(bool), want):
        out.append(Violation(
            "kernel-constants", "neuron_mask_to_block_mask",
            "block mask does not keep exactly the blocks with a surviving "
            "neuron"))
    return out


# ---------------------------------------------------------------------------
# registry / driver

KERNEL_CHECKS: Dict[str, Callable[[], List[Violation]]] = {
    "kernel-ffn-tiles": check_ffn_tile_eligibility,
    "kernel-head-layout": check_head_layouts,
    "kernel-mask-shapes": check_mask_shape_rejection,
    "unit-specs": check_unit_specs,
    "kernel-constants": check_block_constants,
}


def run_kernel_contracts(progress=None) -> List[Violation]:
    out = []
    for name, fn in KERNEL_CHECKS.items():
        if progress:
            progress(name)
        try:
            out.extend(fn())
        except Exception as e:                       # noqa: BLE001
            out.append(Violation(name, fn.__name__,
                                 f"check crashed: {type(e).__name__}: {e}"))
    return out
