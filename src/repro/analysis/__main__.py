"""CLI driver: ``python -m repro.analysis [--lint|--contracts|--kernels|--all]``.

Exit status 0 when every selected pass is clean, 1 otherwise — the CI
``static-analysis`` job gates on it (see .github/workflows/ci.yml and the
README's "Checking your changes" section).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis for the FLuID repro: AST lint, "
                    "trace-time contracts, kernel shape contracts.")
    ap.add_argument("--lint", action="store_true",
                    help="AST lint (tracer safety, dtype discipline, "
                         "donation, policy registration)")
    ap.add_argument("--contracts", action="store_true",
                    help="trace-time contracts (no-f64, single-trace, "
                         "dropped-dW-zero)")
    ap.add_argument("--kernels", action="store_true",
                    help="kernel shape/grammar contracts (static sweep)")
    ap.add_argument("--contract", action="append", metavar="NAME",
                    help="run only the named trace-time contract(s) "
                         "(repeatable; see analysis.contracts.CHECKS)")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when none is selected)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs for --lint (default: src)")
    args = ap.parse_args(argv)

    if not (args.lint or args.contracts or args.kernels or args.contract):
        args.all = True
    problems = 0

    if args.contract and not args.all:
        from repro.analysis.contracts import run_contracts
        t0 = time.time()
        vs = run_contracts(
            progress=lambda n: print(f"[contracts] {n} ...", flush=True),
            only=args.contract)
        for v in vs:
            print(v)
        print(f"[contracts] {len(vs)} violation(s) "
              f"in {time.time() - t0:.1f}s")
        return 1 if vs else 0

    if args.lint or args.all:
        from repro.analysis.lint import lint_paths
        t0 = time.time()
        findings = lint_paths(args.paths or ["src"])
        for f in findings:
            print(f)
        print(f"[lint] {len(findings)} finding(s) in {time.time() - t0:.1f}s")
        problems += len(findings)

    if args.contracts or args.all:
        from repro.analysis.contracts import run_contracts
        t0 = time.time()
        vs = run_contracts(
            progress=lambda n: print(f"[contracts] {n} ...", flush=True))
        for v in vs:
            print(v)
        print(f"[contracts] {len(vs)} violation(s) "
              f"in {time.time() - t0:.1f}s")
        problems += len(vs)

    if args.kernels or args.all:
        from repro.analysis.kernel_contracts import run_kernel_contracts
        t0 = time.time()
        vs = run_kernel_contracts(
            progress=lambda n: print(f"[kernels] {n} ...", flush=True))
        for v in vs:
            print(v)
        print(f"[kernels] {len(vs)} violation(s) in {time.time() - t0:.1f}s")
        problems += len(vs)

    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
