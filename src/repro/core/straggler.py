"""Straggler detection + sub-model sizing from profiled client latencies.

The paper's rule (§5):
  * T_target = the next-slowest (non-straggler) client's end-to-end time;
  * Speedup_i = T_straggler_i / T_target;
  * r_i = the predefined sub-model size closest to 1/Speedup_i (training
    time is linear in sub-model size — paper App. A.3).
Recalibration happens every calibration step, so the straggler cohort can
change at runtime (paper Fig. 4b).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

DEFAULT_SIZES = (0.5, 0.65, 0.75, 0.85, 0.95, 1.0)


@dataclass
class StragglerPlan:
    stragglers: List[int]
    t_target: float
    speedups: Dict[int, float]
    rates: Dict[int, float]         # r_i per straggler


def detect_stragglers(latencies: Dict[int, float],
                      frac: Optional[float] = None,
                      gap_factor: float = 1.10) -> List[int]:
    """If frac given: slowest ceil(frac*C) clients. Else: every client more
    than gap_factor slower than the next-slowest one below it."""
    ids = sorted(latencies, key=lambda c: latencies[c], reverse=True)
    if frac is not None:
        k = max(1, int(round(frac * len(ids))))
        return ids[:k]
    out = []
    for i, c in enumerate(ids[:-1]):
        nxt = latencies[ids[i + 1]]
        if latencies[c] > gap_factor * nxt:
            out.append(c)
        else:
            break
    return out


def pick_rate(speedup: float, sizes: Sequence[float] = DEFAULT_SIZES) -> float:
    """Predefined size closest to 1/speedup (never the full model)."""
    want = 1.0 / max(speedup, 1.0)
    cand = [s for s in sizes if s < 1.0]
    return min(cand, key=lambda s: abs(s - want))


def plan(latencies: Dict[int, float], frac: Optional[float] = None,
         sizes: Sequence[float] = DEFAULT_SIZES,
         gap_factor: float = 1.10) -> StragglerPlan:
    stragglers = detect_stragglers(latencies, frac=frac,
                                   gap_factor=gap_factor)
    non = [c for c in latencies if c not in stragglers]
    if not stragglers or not non:
        return StragglerPlan([], max(latencies.values(), default=0.0), {}, {})
    t_target = max(latencies[c] for c in non)   # next-slowest client
    speedups = {c: latencies[c] / t_target for c in stragglers}
    rates = {c: pick_rate(s, sizes) for c, s in speedups.items()}
    return StragglerPlan(stragglers, t_target, speedups, rates)
