"""Straggler detection + sub-model sizing from profiled client latencies.

The paper's rule (§5):
  * T_target = the next-slowest (non-straggler) client's end-to-end time;
  * Speedup_i = T_straggler_i / T_target;
  * r_i = the predefined sub-model size closest to 1/Speedup_i (training
    time is linear in sub-model size — paper App. A.3).
Recalibration happens every calibration step, so the straggler cohort can
change at runtime (paper Fig. 4b).
"""
from __future__ import annotations

import math

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

DEFAULT_SIZES = (0.5, 0.65, 0.75, 0.85, 0.95, 1.0)


@dataclass
class StragglerPlan:
    stragglers: List[int]
    t_target: float
    speedups: Dict[int, float]
    rates: Dict[int, float]         # r_i per straggler


def detect_stragglers(latencies: Dict[int, float],
                      frac: Optional[float] = None,
                      gap_factor: float = 1.10) -> List[int]:
    """If frac given: slowest round(frac*C) clients (at least one for any
    frac > 0; frac == 0.0 selects nobody — it used to flag one client
    anyway through an unconditional max(1, ...), which made "dropout off"
    configs silently run dropout). frac outside [0, 1] is a ValueError
    rather than a silent over-selection. Else: the slow *band* — everyone
    above the largest adjacent gap in the sorted latencies, provided that
    gap exceeds gap_factor. The split must tolerate ties: population
    cohorts hold many stragglers at the *same* slow speed, so a walk that
    stops at the first non-gapped adjacent pair would never see past the
    tied band (it did, before the population layer)."""
    ids = sorted(latencies, key=lambda c: latencies[c], reverse=True)
    if frac is not None:
        if not 0.0 <= frac <= 1.0:
            raise ValueError(f"frac must be in [0, 1], got {frac}")
        if frac == 0.0:
            return []
        k = max(1, int(round(frac * len(ids))))
        return ids[:k]
    if len(ids) < 2:
        return []
    ratios = [latencies[ids[i]] / max(latencies[ids[i + 1]], 1e-12)
              for i in range(len(ids) - 1)]
    g = max(range(len(ratios)), key=ratios.__getitem__)
    return ids[:g + 1] if ratios[g] > gap_factor else []


def detect_band(latencies: Dict[int, float],
                gap_factor: float = 1.10) -> List[int]:
    """Population-robust straggler band split (the store-backed path).

    Adjacent-gap detection is noise-dominated at population cohort sizes:
    with ~3% multiplicative sim-time noise, the extreme order statistics
    of a 1.3x-slow band and the fast cluster touch once a cohort has
    thousands of draws, so no adjacent pair ever shows a 1.10 ratio. The
    bimodal *structure* survives any cohort size. Two candidate cuts over
    the sorted latencies, each accepted only if the two groups' medians
    are more than gap_factor apart (a unimodal cluster splits into halves
    ~1.08x apart at this repo's noise levels, under the 1.10 bar):

      1. the 1-D two-means (Otsu) cut — minimizes within-group variance;
         finds a slow *band* of any size, but prefers halving a wide
         cluster over isolating one outlier (absolute-SS objective);
      2. fallback: the largest-adjacent-difference cut — isolates a lone
         straggler cleanly, but at thousands of draws the biggest spacing
         sits in the extreme tail, not the inter-mode dip.

    Clients above an accepted cut still pass an individual latency >
    gap_factor * median(fast side) test, so a stray fast draw inside the
    dip is not penalized. Slowest-first, like detect_stragglers."""
    if len(latencies) < 3:
        return detect_stragglers(latencies, gap_factor=gap_factor)
    ids = sorted(latencies, key=latencies.__getitem__)
    x = np.asarray([latencies[c] for c in ids], np.float64)
    n = x.size

    def accept(cut):
        ref = float(np.median(x[:cut]))
        if not float(np.median(x[cut:])) > gap_factor * ref:
            return None
        return [c for c in reversed(ids[cut:])
                if latencies[c] > gap_factor * ref] or None

    cs, css = np.cumsum(x), np.cumsum(x * x)
    k = np.arange(1, n)
    s0, ss0 = cs[:-1], css[:-1]
    s1, ss1 = cs[-1] - s0, css[-1] - ss0
    within = (ss0 - s0 * s0 / k) + (ss1 - s1 * s1 / (n - k))
    band = accept(int(np.argmin(within)) + 1)
    if band is None:
        band = accept(int(np.argmax(np.diff(x))) + 1)
    return band or []


def pick_rate(speedup: float, sizes: Sequence[float] = DEFAULT_SIZES) -> float:
    """Predefined size closest to 1/speedup (never the full model)."""
    want = 1.0 / max(speedup, 1.0)
    cand = [s for s in sizes if s < 1.0]
    return min(cand, key=lambda s: abs(s - want))


def plan(latencies: Dict[int, float], frac: Optional[float] = None,
         sizes: Sequence[float] = DEFAULT_SIZES,
         gap_factor: float = 1.10) -> StragglerPlan:
    stragglers = detect_stragglers(latencies, frac=frac,
                                   gap_factor=gap_factor)
    return _plan_with(latencies, stragglers, sizes)


def _plan_with(latencies: Dict[int, float], stragglers: List[int],
               sizes: Sequence[float]) -> StragglerPlan:
    non = [c for c in latencies if c not in stragglers]
    if not stragglers or not non:
        return StragglerPlan([], max(latencies.values(), default=0.0), {}, {})
    t_target = max(latencies[c] for c in non)   # next-slowest client
    speedups = {c: latencies[c] / t_target for c in stragglers}
    rates = {c: pick_rate(s, sizes) for c, s in speedups.items()}
    return StragglerPlan(stragglers, t_target, speedups, rates)


def plan_from_store(store, client_ids: Sequence[int],
                    frac: Optional[float] = None,
                    sizes: Sequence[float] = DEFAULT_SIZES,
                    gap_factor: float = 1.10) -> StragglerPlan:
    """`plan` fed from a ClientStore's speed history instead of a per-round
    Python dict (fl/population.py).

    `store` is duck-typed: anything exposing `last_latency(ids)` — the most
    recent full-model-equivalent observation per client — works. Clients in
    `client_ids` with no observation yet (rounds_participated == 0, latency
    reported as NaN) are excluded, exactly as an absent dict key would be.
    Detection uses `detect_band` (density-dip split) instead of the
    adjacent-gap rule: population cohorts hold many stragglers at tied
    speeds and enough draws that sim-time noise fills any adjacent gap,
    while the dip between the cluster and the band survives any cohort
    size. On small clearly-separated cohorts both rules agree, so store-
    backed calibration matches the legacy `plan(latencies)` there. An
    explicit `frac` bypasses detection entirely, exactly as in `plan`.
    """
    ids = list(client_ids)
    last = np.asarray(store.last_latency(ids), np.float64)
    latencies = {cid: float(t) for cid, t in zip(ids, last)
                 if np.isfinite(t)}
    if not latencies:
        return StragglerPlan([], 0.0, {}, {})
    if frac is not None:
        return plan(latencies, frac=frac, sizes=sizes,
                    gap_factor=gap_factor)
    return _plan_with(latencies,
                      detect_band(latencies, gap_factor=gap_factor), sizes)


# ---------------------------------------------------------------------------
# Arrival-process model (asynchronous rounds, fl/async_rounds.py)

@dataclass
class ArrivalModel:
    """What happens to a dispatched client between "starts training" and
    "its delta reaches the server" — the arrival process of the async
    buffered backend (fl/async_rounds.py).

    The *base* latency comes from the client speed model
    (SimClient._sim_time, incl. its lognormal heavy tail via `tail_sigma`
    on the client, so the synchronous baseline experiences the identical
    distribution). This model layers the async-only failure modes on top:

      * `tail_sigma`  — extra multiplicative lognormal spread applied only
        to async arrivals (network variance not visible to a barrier that
        already waits for the max). Usually 0.0 for fair benchmarks.
      * `drop_prob`   — per-dispatch probability the client falls off
        mid-round. A dropped client is NOT lost: it reconnects after an
        Exp(reconnect_mean) pause, resumes from where it stopped, and its
        delta lands in a later buffer with higher staleness.
      * `max_drops`   — cap on consecutive dropouts per dispatch.

    Draws come from a private seeded RandomState so arrival randomness is
    reproducible and independent of the clients' own RNG streams. With
    everything at zero the model is an exact pass-through — `draw(t)`
    returns (t, 0) without consuming randomness — which the zero-spread
    fleet==async equivalence test relies on."""
    tail_sigma: float = 0.0
    drop_prob: float = 0.0
    reconnect_mean: float = 30.0
    max_drops: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.tail_sigma < 0.0:
            raise ValueError(f"tail_sigma must be >= 0, got {self.tail_sigma}")
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(f"drop_prob must be in [0, 1), "
                             f"got {self.drop_prob}")
        self._rng = np.random.RandomState(self.seed)

    def draw(self, base: float):
        """(arrival latency, n_dropouts) for one dispatched job whose
        compute+transfer time is `base` emulated seconds."""
        lat = float(base)
        if self.tail_sigma > 0.0:
            lat *= math.exp(self.tail_sigma * float(self._rng.randn()))
        drops = 0
        while (self.drop_prob > 0.0 and drops < self.max_drops
               and self._rng.rand() < self.drop_prob):
            lat += float(self._rng.exponential(self.reconnect_mean))
            drops += 1
        return max(lat, 1e-6), drops
