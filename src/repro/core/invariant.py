"""Invariant-neuron statistics and drop-threshold calibration (paper §4, §5).

A neuron's *update statistic* for one client is the maximum relative weight
change over all weights that produce it:

    g_i = max_w |w(t) - w(t-1)| / (|w(t-1)| + eps)

(the paper's "minimum g such that g >= (w(t)-w(t-1))/w(t-1)" — i.e. the
tightest bound covering every weight of the neuron).

A neuron is *invariant* at threshold th when g_i <= th for the **majority of
non-straggler clients** (stragglers train sub-models, so the server never
uses their updates for this). The initial threshold is the client-average of
the per-client minimum neuron stat; it is then incremented geometrically
until at least the target number of neurons is invariant (Algorithm 1,
lines 9 / 22).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-8
TH_GROWTH = 1.25


def _get(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _per_unit(arr, axis, tile, size):
    """Group an array's producer weights by unit: -> (size, -1).

    Mirrors submodel.expand_indices' grammar: tile>0 is tile-major
    (unit index fastest along the axis), tile<0 is unit-major (each unit
    owns |tile| contiguous slots — the attention-head layout)."""
    a = jnp.moveaxis(arr, axis, 0)
    if tile < 0:
        return a.reshape(size, -1)
    return a.reshape(tile, size, -1).transpose(1, 0, 2).reshape(size, -1)


def neuron_stats_for_group(prev_tree, new_tree, group,
                           kind: str = "norm") -> jnp.ndarray:
    """Per-neuron relative update statistic over the group's producers.

    kind="norm" (default): ||Δw|| / (||w(t-1)|| + eps) per neuron — one
    relative "percent difference g of the neuron" (paper §5). kind="max":
    per-weight max relative delta (dominated by near-zero weights; kept for
    ablation). Returns (size,) float32."""
    size = group["size"]
    if kind == "max":
        stats = jnp.zeros((size,), jnp.float32)
        for path, axis, tile in group["out"]:
            w0 = _get(prev_tree, path).astype(jnp.float32)
            w1 = _get(new_tree, path).astype(jnp.float32)
            rel = jnp.abs(w1 - w0) / (jnp.abs(w0) + EPS)
            rel = _per_unit(rel, axis, tile, size)
            stats = jnp.maximum(stats, rel.max(axis=1))
        return stats
    num = jnp.zeros((size,), jnp.float32)
    den = jnp.zeros((size,), jnp.float32)
    for path, axis, tile in group["out"]:
        w0 = _get(prev_tree, path).astype(jnp.float32)
        w1 = _get(new_tree, path).astype(jnp.float32)
        d2 = _per_unit(jnp.square(w1 - w0), axis, tile, size)
        w2 = _per_unit(jnp.square(w0), axis, tile, size)
        num = num + d2.sum(axis=1)
        den = den + w2.sum(axis=1)
    return jnp.sqrt(num) / (jnp.sqrt(den) + EPS)


def neuron_stats(prev_tree, new_tree, unit_specs,
                 kind: str = "norm") -> Dict[str, jnp.ndarray]:
    return {g["name"]: neuron_stats_for_group(prev_tree, new_tree, g, kind)
            for g in unit_specs}


def initial_threshold(per_client_stats: Sequence[Dict[str, jnp.ndarray]]):
    """Average over clients of the min percent-update over all neurons."""
    mins = []
    for cs in per_client_stats:
        allv = jnp.concatenate([v.ravel() for v in cs.values()])
        mins.append(allv.min())
    return float(jnp.mean(jnp.stack(mins)))


def invariant_counts(per_client_stats: Sequence[Dict[str, jnp.ndarray]],
                     th: float) -> Dict[str, np.ndarray]:
    """Per group: #clients for which each neuron is below th."""
    out = {}
    for g in per_client_stats[0]:
        votes = jnp.stack([cs[g] <= th for cs in per_client_stats])
        out[g] = np.asarray(votes.sum(axis=0))
    return out


def mean_stats(per_client_stats) -> Dict[str, np.ndarray]:
    return {g: np.asarray(jnp.stack([cs[g] for cs in per_client_stats])
                          .mean(axis=0))
            for g in per_client_stats[0]}


def invariant_mask(per_client_stats, th: float) -> Dict[str, np.ndarray]:
    """Neurons invariant for the strict majority of clients."""
    n = len(per_client_stats)
    counts = invariant_counts(per_client_stats, th)
    return {g: c > n / 2 for g, c in counts.items()}


def count_invariant(per_client_stats, th: float) -> int:
    m = invariant_mask(per_client_stats, th)
    return int(sum(v.sum() for v in m.values()))


def calibrate_threshold(per_client_stats, n_drop_target: int, th0: float,
                        max_iters: int = 200) -> float:
    """Increment th until #invariant >= n_drop_target (Algorithm 1 l.22)."""
    th = max(float(th0), EPS)
    for _ in range(max_iters):
        if count_invariant(per_client_stats, th) >= n_drop_target:
            return th
        th *= TH_GROWTH
    return th


def calibrate_threshold_per_group(per_client_stats, drop_targets: Dict[str, int],
                                  th0: float, max_iters: int = 200
                                  ) -> Dict[str, float]:
    """Per-layer thresholds (paper: 'FLuID can have a different drop
    threshold for each layer')."""
    out = {}
    for g, target in drop_targets.items():
        th = max(float(th0), EPS)
        stats_g = [{g: cs[g]} for cs in per_client_stats]
        for _ in range(max_iters):
            if count_invariant(stats_g, th) >= target:
                break
            th *= TH_GROWTH
        out[g] = th
    return out
