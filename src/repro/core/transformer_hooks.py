"""FLuID hooks for the big-architecture path (mask-based sub-models).

The FL simulator drops neurons by *physical extraction* (core/submodel.py).
At datacenter scale the same math is applied through masks so one compiled
train step serves every sub-model (DESIGN.md §2): per layer, FFN hidden
units (and MoE expert-units / whole experts) are scored by the same
norm-relative update statistic and the lowest-stat units are masked.

``block128=True`` rounds the kept set to 128-aligned blocks (MXU-native
block-invariant dropout — the beyond-paper TPU adaptation) matching
kernels/masked_ffn.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dropout import keep_count
from repro.models import transformer


def _ffn_stat(prev_l, new_l):
    """Per-hidden-unit norm-relative delta for one (stacked) layer tree.
    Works on (R, d, f) w_in / (R, f, d) w_out stacks; returns (R, f)."""
    num = 0.0
    den = 0.0
    for key, axis in (("w_in", 1), ("w_gate", 1), ("w_out", 2)):
        if key not in prev_l:
            continue
        w0 = prev_l[key].astype(jnp.float32)
        w1 = new_l[key].astype(jnp.float32)
        red = axis  # the non-unit trailing axis
        num = num + jnp.square(w1 - w0).sum(axis=red)
        den = den + jnp.square(w0).sum(axis=red)
    return jnp.sqrt(num) / (jnp.sqrt(den) + 1e-8)


def ffn_unit_stats(prev_params, new_params, cfg: ModelConfig):
    """Per-segment list of per-unit {'l<i>': {'ffn': (R, f)}} stats."""
    segs = transformer.build_segments(cfg)
    out = []
    for si, seg in enumerate(segs):
        seg_prev = prev_params["stack"][f"seg{si}"]
        seg_new = new_params["stack"][f"seg{si}"]
        unit = {}
        for i, (mixer, ffn) in enumerate(seg.unit):
            lp, ln = seg_prev[f"l{i}"], seg_new[f"l{i}"]
            entry = {}
            if ffn == "dense":
                entry["ffn"] = _ffn_stat(lp["ffn"], ln["ffn"])
            elif ffn == "cmix":
                entry["ffn"] = _ffn_stat(lp["cmix"], ln["cmix"])
            elif ffn == "moe":
                w0 = lp["moe"]["w_in"].astype(jnp.float32)
                w1 = ln["moe"]["w_in"].astype(jnp.float32)
                num = jnp.square(w1 - w0).sum(axis=2)      # (R, E, f)
                den = jnp.square(w0).sum(axis=2)
                entry["moe"] = jnp.sqrt(num) / (jnp.sqrt(den) + 1e-8)
                entry["experts"] = entry["moe"].mean(axis=-1)   # (R, E)
            unit[f"l{i}"] = entry
        out.append(unit)
    return out


def _mask_from_stats(stats: np.ndarray, r: float, block128: bool):
    """Keep the (r * n) highest-stat units along the last axis."""
    n = stats.shape[-1]
    k = keep_count(n, r)
    if block128 and n % 128 == 0:
        blocks = stats.reshape(*stats.shape[:-1], n // 128, 128).mean(-1)
        kb = max(1, int(round(n // 128 * r)))
        thresh = np.sort(blocks, axis=-1)[..., -kb][..., None]
        bm = (blocks >= thresh).astype(np.float32)
        return np.repeat(bm, 128, axis=-1)
    thresh = np.sort(stats, axis=-1)[..., -k][..., None]
    return (stats >= thresh).astype(np.float32)


def build_masks(unit_stats, cfg: ModelConfig, r: float,
                block128: bool = True, drop_experts: bool = False):
    """Masks pytree for model.forward_seq(masks=...) from ffn_unit_stats."""
    out = []
    for seg_stats in unit_stats:
        unit = {}
        for lname, entry in seg_stats.items():
            m = {}
            if "ffn" in entry:
                m["ffn"] = jnp.asarray(
                    _mask_from_stats(np.asarray(entry["ffn"]), r, block128))
            if "moe" in entry:
                m["moe"] = jnp.asarray(
                    _mask_from_stats(np.asarray(entry["moe"]), r, block128))
                if drop_experts:
                    m["experts"] = jnp.asarray(_mask_from_stats(
                        np.asarray(entry["experts"]), r, False))
            unit[lname] = m
        out.append(unit)
    return out


def full_masks(cfg: ModelConfig):
    """All-ones masks (the r=1.0 sub-model; handy for jit signature parity)."""
    segs = transformer.build_segments(cfg)
    out = []
    for seg in segs:
        unit = {}
        for i, (mixer, ffn) in enumerate(seg.unit):
            m = {}
            if ffn in ("dense", "cmix"):
                m["ffn"] = jnp.ones((seg.repeats, cfg.d_ff if ffn == "dense"
                                     else cfg.d_ff), jnp.float32)
            elif ffn == "moe":
                m["moe"] = jnp.ones((seg.repeats, cfg.n_experts, cfg.moe_ff),
                                    jnp.float32)
            unit[f"l{i}"] = m
        out.append(unit)
    return out
