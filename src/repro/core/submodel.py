"""Physical sub-model extraction / re-embedding over unit-spec'd param trees.

extract():      gather the kept rows/cols -> a *smaller* param tree the
                straggler actually trains (less compute AND less transfer,
                exactly the paper's mechanism).
embed_delta():  scatter a sub-model delta back into full-model coordinates,
                plus the 0/1 participation mask used by masked FedAvg.

Tile factors expand kept neuron indices into structured axes
(conv->FC flatten, LSTM gate blocks) — see models/small.py for the grammar.
"""
from __future__ import annotations

import copy
from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _get(tree, path):
    node = tree
    for p in path.split("/"):
        node = node[p]
    return node


def _set(tree, path, value):
    parts = path.split("/")
    node = tree
    for p in parts[:-1]:
        node = node[p]
    node[parts[-1]] = value


def expand_indices(keep: np.ndarray, tile: int, size: int) -> np.ndarray:
    """Kept unit indices -> kept axis indices.

    tile > 0 (tile-major): {t*size + i : t < tile, i in keep} — units are
    interleaved per tile (conv->FC flatten, LSTM gate blocks).
    tile < 0 (unit-major): {i*|tile| + t : i in keep, t < |tile|} — each
    unit owns |tile| *contiguous* slots, the attention-head layout (head
    index slow, head-dim fast; see models/kernel_models.py and
    kernels/masked_attn.py). Axis length must equal size * |tile|."""
    if tile == 1:
        return keep
    if tile < 0:
        t = -tile
        return (keep[:, None] * t + np.arange(t)[None, :]).reshape(-1)
    return (np.arange(tile)[:, None] * size + keep[None, :]).reshape(-1)


def _axis_indices(unit_specs, keep_map) -> Dict[str, Dict[int, np.ndarray]]:
    """path -> {axis: kept index array}."""
    out: Dict[str, Dict[int, np.ndarray]] = {}
    for g in unit_specs:
        keep = keep_map[g["name"]]
        for role in ("out", "in"):
            for path, axis, tile in g[role]:
                idx = expand_indices(np.asarray(keep), tile, g["size"])
                out.setdefault(path, {})
                if axis in out[path]:
                    # same array referenced twice on one axis: intersect
                    out[path][axis] = np.intersect1d(out[path][axis], idx)
                else:
                    out[path][axis] = idx
    return out


def extract(params, unit_specs, keep_map):
    """Gather the sub-model. Returns a new tree (shared leaves where untouched)."""
    sub = copy.deepcopy(jax.tree.map(lambda x: x, params))
    for path, axes in _axis_indices(unit_specs, keep_map).items():
        arr = _get(sub, path)
        for axis, idx in sorted(axes.items()):
            arr = jnp.take(arr, jnp.asarray(idx), axis=axis)
        _set(sub, path, arr)
    return sub


def keep_mask(full_like, unit_specs, keep_map):
    """Dense 0/1 participation mask in full-model coordinates.

    1.0 exactly where a straggler with this keep_map trains: the kept
    rows/cols of every array a group touches, and every array no group
    touches (transferred whole, fully trained). This is the dense-mask dual
    of extract(): forward(mask * params) == forward(extract(params)) on the
    kept coordinates, which is what lets every dropout rate share one
    compiled program (see fl/fleet.py)."""
    mask = jax.tree.map(lambda x: jnp.ones_like(x, dtype=jnp.float32),
                        full_like)
    for path, axes in _axis_indices(unit_specs, keep_map).items():
        target = _get(full_like, path)
        idxs = [np.arange(n) for n in target.shape]
        for axis, idx in axes.items():
            idxs[axis] = np.asarray(idx)
        grid = jnp.ix_(*[jnp.asarray(i) for i in idxs])
        m = jnp.zeros(target.shape, jnp.float32)
        _set(mask, path, m.at[grid].set(1.0))
    return mask


def apply_mask(params, mask):
    """Zero the dropped coordinates — the dense-mask analogue of extract()."""
    return jax.tree.map(lambda p, m: p * m.astype(p.dtype), params, mask)


def embed_delta(sub_delta, full_like, unit_specs, keep_map):
    """Scatter sub-model delta into full coordinates.

    Returns (full_delta, mask) — mask has 1.0 exactly where the straggler
    trained (== keep_mask for this keep_map, built here from the same index
    grids as the delta scatter to avoid a second _axis_indices pass).
    Arrays untouched by any group (same shape in the sub-model, fully
    trained by the straggler) pass through verbatim with mask=1."""
    full_delta = jax.tree.map(
        lambda s, f: (s.astype(f.dtype) if s.shape == f.shape
                      else jnp.zeros_like(f)),
        sub_delta, full_like)
    mask = jax.tree.map(lambda x: jnp.ones_like(x, dtype=jnp.float32),
                        full_like)
    for path, axes in _axis_indices(unit_specs, keep_map).items():
        target = _get(full_like, path)
        idxs = [np.arange(n) for n in target.shape]
        for axis, idx in axes.items():
            idxs[axis] = np.asarray(idx)
        grid = jnp.ix_(*[jnp.asarray(i) for i in idxs])
        zero = jnp.zeros_like(target)
        _set(full_delta, path, zero.at[grid].set(_get(sub_delta, path)
                                                 .astype(target.dtype)))
        m = jnp.zeros(target.shape, jnp.float32)
        _set(mask, path, m.at[grid].set(1.0))
    return full_delta, mask


def submodel_sizes(params, unit_specs, keep_map):
    """(#params sub, #params full) — the transfer/compute saving."""
    sub = extract(params, unit_specs, keep_map)
    n_sub = sum(x.size for x in jax.tree.leaves(sub))
    n_full = sum(x.size for x in jax.tree.leaves(params))
    return n_sub, n_full
