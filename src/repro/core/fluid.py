"""FLuID server — Algorithm 1 of the paper, framework-level.

The server is agnostic to how clients execute (real devices, simulated
clients, or pod-level client shards): anything satisfying the Client
protocol works. Per calibration step it (1) profiles end-to-end client
times, (2) re-detects stragglers and T_target, (3) re-derives per-straggler
dropout rates r_i from the linear time model, (4) increments the drop
threshold until enough neurons are invariant, and (5) extracts tailored
sub-models via the selected policy (random / ordered / invariant).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import invariant as inv
from repro.core import straggler as strag
from repro.core import submodel as sub
from repro.core.aggregate import ClientUpdate, aggregate
from repro.core.dropout import get_policy, keep_count


@dataclass
class FluidConfig:
    method: str = "invariant"              # random | ordered | invariant | none
    submodel_sizes: Sequence[float] = strag.DEFAULT_SIZES
    fixed_rate: Optional[float] = None     # force one r for all stragglers
    straggler_frac: Optional[float] = None  # None => auto gap detection
    calibrate_every: int = 1
    warmup_rounds: int = 1                 # full-model rounds before dropout
    seed: int = 0


@dataclass
class RoundLog:
    round: int = 0
    round_time: float = 0.0                # max client sim time (sync FL)
    straggler_time: float = 0.0
    t_target: float = 0.0
    stragglers: List[int] = field(default_factory=list)
    rates: Dict[int, float] = field(default_factory=dict)
    threshold: float = 0.0
    invariant_frac: float = 0.0
    calib_time: float = 0.0                # server-side overhead (real s)
    accuracy: float = float("nan")


class FluidServer:
    def __init__(self, params, unit_specs, clients, cfg: FluidConfig,
                 eval_fn: Optional[Callable] = None, engine=None):
        self.params = params
        self.unit_specs = unit_specs
        self.clients = list(clients)
        self.cfg = cfg
        self.eval_fn = eval_fn
        self.engine = engine          # fl.fleet.FleetEngine or None
        self.policy = get_policy(
            cfg.method if cfg.method != "none" else "ordered",
            unit_specs, seed=cfg.seed)
        self.th: Optional[float] = None
        self.plan: Optional[strag.StragglerPlan] = None
        self.round = 0
        self.history: List[RoundLog] = []

    # ------------------------------------------------------------------ utils
    def _total_neurons(self) -> int:
        return sum(g["size"] for g in self.unit_specs)

    def _drop_target(self, rates: Dict[int, float]) -> int:
        if not rates:
            return 0
        r_min = min(rates.values())
        return sum(g["size"] - keep_count(g["size"], r_min)
                   for g in self.unit_specs)

    # ------------------------------------------------------------------ round
    def run_round(self, eval_now: bool = False) -> RoundLog:
        cfg = self.cfg
        log = RoundLog(round=self.round)
        use_dropout = (cfg.method != "none"
                       and self.round >= cfg.warmup_rounds
                       and self.plan is not None
                       and bool(self.plan.stragglers))

        # -------- sub-model assignment (shared by both execution backends)
        keep_maps: Dict[int, dict] = {}
        rates_used: Dict[int, float] = {}
        if use_dropout:
            for cid in self.plan.stragglers:
                r = (cfg.fixed_rate if cfg.fixed_rate is not None
                     else self.plan.rates[cid])
                keep_maps[cid] = self.policy.keep_map(r)
                rates_used[cid] = r

        # -------- broadcast + local training
        prev = self.params
        cohort = None
        updates: List[ClientUpdate] = []
        if self.engine is not None:
            # one vmapped program for the whole cohort (fl/fleet.py)
            cohort = self.engine.run_cohort(self.params, keep_maps,
                                            rates_used)
            actual = dict(cohort.sim_times)
        else:
            for c in self.clients:
                if c.id in keep_maps:
                    keep, r = keep_maps[c.id], rates_used[c.id]
                    sub_params = sub.extract(self.params, self.unit_specs,
                                             keep)
                    u = c.train(sub_params, keep_map=keep, rate=r)
                    full_delta, mask = sub.embed_delta(
                        u.delta, self.params, self.unit_specs, keep)
                    u = ClientUpdate(full_delta, u.n_samples, mask,
                                     u.sim_time, u.real_time, c.id)
                else:
                    u = c.train(self.params)
                updates.append(u)
            actual = {u.client_id: u.sim_time for u in updates}

        # full-model-equivalent latency: a straggler that trained a sub-model
        # of size r would take time/r on the full model (linear model, A.3)
        latencies = {cid: t / rates_used.get(cid, 1.0)
                     for cid, t in actual.items()}
        log.round_time = max(actual.values())
        if self.plan and self.plan.stragglers:
            st = [actual[c] for c in self.plan.stragglers if c in actual]
            log.straggler_time = max(st) if st else 0.0
            log.t_target = self.plan.t_target
            log.stragglers = list(self.plan.stragglers)
            log.rates = dict(self.plan.rates)

        # -------- aggregate
        if cohort is not None:
            self.params = cohort.aggregate(self.params)
        else:
            self.params = aggregate(self.params, updates)

        # -------- calibration (server-side; wall-clock measured as overhead)
        t0 = time.perf_counter()
        if self.round % cfg.calibrate_every == 0:
            if cohort is not None:
                per_client = cohort.non_straggler_stats(prev)
            else:
                per_client = [
                    inv.neuron_stats(prev,
                                     jax.tree.map(lambda p, d: p + d,
                                                  prev, u.delta),
                                     self.unit_specs)
                    for u in updates if u.mask is None]
            if per_client:
                if self.th is None:
                    self.th = inv.initial_threshold(per_client)
                self.plan = strag.plan(latencies, frac=cfg.straggler_frac,
                                       sizes=cfg.submodel_sizes)
                target = self._drop_target(
                    {c: cfg.fixed_rate for c in self.plan.stragglers}
                    if cfg.fixed_rate is not None else self.plan.rates)
                if target:
                    self.th = inv.calibrate_threshold(per_client, target,
                                                      self.th)
                self.policy.observe(per_client, self.th)
                log.threshold = float(self.th)
                log.invariant_frac = (inv.count_invariant(per_client, self.th)
                                      / self._total_neurons())
        log.calib_time = time.perf_counter() - t0

        if eval_now and self.eval_fn is not None:
            log.accuracy = float(self.eval_fn(self.params))
        self.history.append(log)
        self.round += 1
        return log

    def run(self, rounds: int, eval_every: int = 0):
        for i in range(rounds):
            ev = bool(eval_every) and ((i + 1) % eval_every == 0
                                       or i == rounds - 1)
            self.run_round(eval_now=ev)
        return self.history
