"""FLuID server — Algorithm 1 of the paper, framework-level.

The server is agnostic to how clients execute: anything satisfying the
RoundBackend contract (fl/rounds.py: sequential / fleet / sharded_fleet)
works, and the backend may change per round — the population driver
(fl/population.py) materializes a fresh cohort backend from the ClientStore
every round. Per calibration step the server (1) records end-to-end client
times into the store's speed history, (2) re-detects stragglers and
T_target from that history, (3) re-derives per-straggler dropout rates r_i
from the linear time model and writes them back to the store, (4)
increments the drop threshold until enough neurons are invariant, and (5)
extracts tailored sub-models via the selected policy (random / ordered /
invariant).

Layering: core/ never imports fl/. The backend and the store are duck-typed
— the store needs `rates_of`, `update_from_round`, `assign_rates`, and
`last_latency` (consumed via core/straggler.plan_from_store); without a
store the server falls back to per-round dicts (legacy standalone use).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import invariant as inv
from repro.core import straggler as strag
from repro.core.dropout import get_policy, keep_count


@dataclass
class FluidConfig:
    method: str = "invariant"              # random | ordered | invariant | none
    submodel_sizes: Sequence[float] = strag.DEFAULT_SIZES
    fixed_rate: Optional[float] = None     # force one r for all stragglers
    straggler_frac: Optional[float] = None  # None => auto gap detection
    calibrate_every: int = 1
    warmup_rounds: int = 1                 # full-model rounds before dropout
    seed: int = 0


@dataclass
class RoundLog:
    round: int = 0
    round_time: float = 0.0                # max client sim time (sync FL)
    clock: float = 0.0                     # virtual wall-clock (async FL)
    staleness_mean: float = 0.0            # buffer staleness (async FL)
    staleness_max: float = 0.0
    straggler_time: float = 0.0
    t_target: float = 0.0
    stragglers: List[int] = field(default_factory=list)
    rates: Dict[int, float] = field(default_factory=dict)
    threshold: float = 0.0
    invariant_frac: float = 0.0
    calib_time: float = 0.0                # server-side overhead (real s)
    accuracy: float = float("nan")


class FluidServer:
    def __init__(self, params, unit_specs, backend=None, cfg=None,
                 eval_fn: Optional[Callable] = None, store=None):
        if cfg is None:
            raise ValueError("FluidServer needs a FluidConfig (cfg=...)")
        self.params = params
        self.unit_specs = unit_specs
        self.backend = backend        # default RoundBackend (fl/rounds.py)
        self.cfg = cfg
        self.eval_fn = eval_fn
        self.store = store            # fl.population.ClientStore or None
        self.policy = get_policy(
            cfg.method if cfg.method != "none" else "ordered",
            unit_specs, seed=cfg.seed)
        self.th: Optional[float] = None
        self.plan: Optional[strag.StragglerPlan] = None
        self.round = 0
        self.history: List[RoundLog] = []

    # ------------------------------------------------------------------ views
    @property
    def engine(self):
        """The fleet engine of the default backend, if any (tests, bench)."""
        return getattr(self.backend, "engine", None)

    @property
    def clients(self):
        return self.backend.clients if self.backend is not None else []

    # ------------------------------------------------------------------ utils
    def _total_neurons(self) -> int:
        return sum(g["size"] for g in self.unit_specs)

    def _drop_target(self, rates: Dict[int, float]) -> int:
        if not rates:
            return 0
        r_min = min(rates.values())
        return sum(g["size"] - keep_count(g["size"], r_min)
                   for g in self.unit_specs)

    def _rate_for(self, cid: int) -> float:
        return (self.cfg.fixed_rate if self.cfg.fixed_rate is not None
                else self.plan.rates[cid])

    # ------------------------------------------------------------------ round
    def run_round(self, eval_now: bool = False, backend=None) -> RoundLog:
        """One synchronous FLuID round via `backend` (default: the one from
        __init__ — the population driver passes a fresh cohort backend
        per round). Store slots are client ids."""
        cfg = self.cfg
        backend = self.backend if backend is None else backend
        if backend is None:
            raise ValueError("no RoundBackend: pass backend= to __init__ "
                             "or run_round")
        ids = [c.id for c in backend.clients]
        log = RoundLog(round=self.round)
        use_dropout = (cfg.method != "none"
                       and self.round >= cfg.warmup_rounds)

        # -------- sub-model assignment: the store's per-client dropout rate
        # (written by the previous calibration) decides who trains what
        keep_maps: Dict[int, dict] = {}
        rates_used: Dict[int, float] = {}
        if use_dropout and self.store is not None:
            for cid, r in zip(ids, self.store.rates_of(ids)):
                if r < 1.0:
                    keep_maps[cid] = self.policy.keep_map(float(r))
                    rates_used[cid] = float(r)
        elif (use_dropout and self.plan is not None
              and bool(self.plan.stragglers)):
            # storeless fallback: read the last plan directly
            for cid in self.plan.stragglers:
                if cid in ids:
                    r = self._rate_for(cid)
                    keep_maps[cid] = self.policy.keep_map(r)
                    rates_used[cid] = r

        # -------- broadcast + local training
        prev = self.params
        result = backend.run_round(self.params, keep_maps, rates_used)
        actual = dict(result.sim_times)

        # An async backend reports arrivals, not the dispatch cohort: who
        # was observed (sim_times), the rate each arrival actually trained
        # (rates_trained — assigned at ITS dispatch, not this round's), and
        # who calibration should reason about (calib_ids). Synchronous
        # backends expose none of these, and every fallback below
        # reproduces the synchronous behavior exactly.
        obs_rates = getattr(result, "rates_trained", None)
        if obs_rates is None:
            obs_rates = rates_used

        # full-model-equivalent latency: a straggler that trained a sub-model
        # of size r would take time/r on the full model (linear model, A.3)
        latencies = {cid: t / obs_rates.get(cid, 1.0)
                     for cid, t in actual.items()}
        log.round_time = max(actual.values())
        log.clock = float(getattr(result, "clock", 0.0))
        stale = getattr(result, "staleness", None)
        if stale is not None and len(stale):
            log.staleness_mean = float(np.mean(stale))
            log.staleness_max = float(np.max(stale))
        if self.plan and self.plan.stragglers:
            st = [actual[c] for c in self.plan.stragglers if c in actual]
            log.straggler_time = max(st) if st else 0.0
            log.t_target = self.plan.t_target
            log.stragglers = list(self.plan.stragglers)
            log.rates = dict(self.plan.rates)

        # -------- record observations (speed history feeds recalibration)
        # obs_ids: whoever was actually observed, in cohort order first
        # (== ids exactly for synchronous backends) then any arrival from
        # an earlier dispatch, in buffer order
        ids_set = set(ids)
        obs_ids = ([c for c in ids if c in actual]
                   + [c for c in actual if c not in ids_set])
        if self.store is not None and obs_ids:
            self.store = self.store.update_from_round(
                np.asarray(obs_ids, np.int32),
                np.asarray([latencies[c] for c in obs_ids], np.float32),
                np.asarray([obs_rates.get(c, 1.0) for c in obs_ids],
                           np.float32))

        # -------- aggregate
        self.params = result.aggregate(self.params)

        # -------- calibration (server-side; wall-clock measured as overhead)
        t0 = time.perf_counter()
        # calibration scope: the clients with fresh observations — the
        # cohort for synchronous backends, this buffer's arrivals for async
        calib_ids = list(getattr(result, "calib_ids", None) or ids)
        if self.round % cfg.calibrate_every == 0:
            per_client = result.non_straggler_stats(prev)
            if per_client:
                if self.th is None:
                    self.th = inv.initial_threshold(per_client)
                if self.store is not None:
                    self.plan = strag.plan_from_store(
                        self.store, calib_ids, frac=cfg.straggler_frac,
                        sizes=cfg.submodel_sizes)
                else:
                    self.plan = strag.plan(latencies,
                                           frac=cfg.straggler_frac,
                                           sizes=cfg.submodel_sizes)
                target = self._drop_target(
                    {c: cfg.fixed_rate for c in self.plan.stragglers}
                    if cfg.fixed_rate is not None else self.plan.rates)
                if target:
                    self.th = inv.calibrate_threshold(per_client, target,
                                                      self.th)
                self.policy.observe(per_client, self.th)
                log.threshold = float(self.th)
                log.invariant_frac = (inv.count_invariant(per_client, self.th)
                                      / self._total_neurons())
                if self.store is not None:
                    # write the new plan back: stragglers get their rate,
                    # everyone else observed returns to the full model
                    stragglers = set(self.plan.stragglers)
                    self.store = self.store.assign_rates(
                        np.asarray(calib_ids, np.int32),
                        np.asarray([self._rate_for(c) if c in stragglers
                                    else 1.0 for c in calib_ids],
                                   np.float32))
        log.calib_time = time.perf_counter() - t0

        if eval_now and self.eval_fn is not None:
            log.accuracy = float(self.eval_fn(self.params))
        self.history.append(log)
        self.round += 1
        return log

    def run(self, rounds: int, eval_every: int = 0):
        for i in range(rounds):
            ev = bool(eval_every) and ((i + 1) % eval_every == 0
                                       or i == rounds - 1)
            self.run_round(eval_now=ev)
        return self.history
