"""Masked FedAvg aggregation (paper Algorithm 1, line 16).

Clients return deltas (new - broadcast). Stragglers' deltas arrive embedded
in full coordinates with a participation mask. The server averages each
element over the clients that actually trained it, weighted by sample count:

    w_new = w + sum_c(n_c * mask_c * delta_c) / sum_c(n_c * mask_c)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


@dataclass
class ClientUpdate:
    delta: dict                 # full-coordinate delta tree
    n_samples: int
    mask: Optional[dict] = None  # None = trained the full model
    sim_time: float = 0.0
    real_time: float = 0.0
    client_id: int = -1


def aggregate(global_params, updates: Sequence[ClientUpdate]):
    """Participation-weighted FedAvg."""
    num = jax.tree.map(jnp.zeros_like, global_params)
    den = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                       global_params)
    for u in updates:
        w = float(u.n_samples)
        if u.mask is None:
            num = jax.tree.map(lambda a, d: a + w * d.astype(a.dtype),
                               num, u.delta)
            den = jax.tree.map(lambda a: a + w, den)
        else:
            num = jax.tree.map(
                lambda a, d, m: a + (w * m * d).astype(a.dtype),
                num, u.delta, u.mask)
            den = jax.tree.map(lambda a, m: a + w * m, den, u.mask)
    return jax.tree.map(
        lambda p, n, d: p + jnp.where(d > 0, n / jnp.maximum(d, 1e-12),
                                      0.0).astype(p.dtype),
        global_params, num, den)
