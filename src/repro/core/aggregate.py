"""Masked FedAvg aggregation (paper Algorithm 1, line 16).

Clients return deltas (new - broadcast). Stragglers' deltas arrive embedded
in full coordinates with a participation mask. The server averages each
element over the clients that actually trained it, weighted by sample count:

    w_new = w + sum_c(n_c * mask_c * delta_c) / sum_c(n_c * mask_c)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


@dataclass
class ClientUpdate:
    delta: dict                 # full-coordinate delta tree
    n_samples: int
    mask: Optional[dict] = None  # None = trained the full model
    sim_time: float = 0.0
    real_time: float = 0.0
    client_id: int = -1


def partial_sums(stacked_deltas, weights, mask_idx, num_masks: int):
    """Shard-local half of the masked FedAvg (hierarchical aggregation).

    Reduces one shard's (Cs, ...) stacked deltas to the two sufficient
    statistics of `aggregate_stacked`:

        num        = sum_c w_c * delta_c            (tree of param-shaped leaves)
        w_per_mask = sum_{c: idx_c=k} w_c           ((K,) float32)

    Because both are plain sums over clients, per-shard partials add across
    shards (and `jax.lax.psum` across devices) to exactly the cohort-level
    statistics — the MaskBank stays replicated, so the denominator
    `sum_k w_per_mask_k * bank_k` is reconstructed after the reduction by
    `combine_partials`. num_masks must be the bank's row count K (static).
    """
    weights = weights.astype(jnp.float32)
    w_per_mask = jax.ops.segment_sum(weights, mask_idx,
                                     num_segments=num_masks)
    num = jax.tree.map(
        lambda d: jnp.tensordot(weights, d.astype(jnp.float32), axes=1),
        stacked_deltas)
    return num, w_per_mask


def combine_partials(global_params, num, w_per_mask, mask_bank):
    """Apply fully-reduced `partial_sums` statistics to the global params:

        w_new = w + num / (sum_k w_per_mask_k * bank_k)   where den > 0.

    The (num, w_per_mask) pair is linear in the clients, so any reduction
    tree over shard partials (sequential adds, psum, …) yields the same
    inputs here up to float summation order.
    """
    den = jax.tree.map(lambda b: jnp.tensordot(w_per_mask, b, axes=1),
                       mask_bank)
    return jax.tree.map(
        lambda p, n, d: p + jnp.where(d > 0, n / jnp.maximum(d, 1e-12),
                                      0.0).astype(p.dtype),
        global_params, num, den)


@jax.jit
def aggregate_stacked(global_params, stacked_deltas, weights,
                      mask_bank, mask_idx):
    """Fused device-side FedAvg over a stacked cohort (fl/fleet.py).

    stacked_deltas: tree of (C, ...) leaves, already mask-zeroed where a
    client did not train (so ``mask_c * delta_c == delta_c``).
    weights: (C,) sample counts. mask_bank: tree of (K, ...) distinct
    participation masks; mask_idx: (C,) int32 mapping client -> bank row
    (row of all-ones for full-model clients).

    Same formula as `aggregate` — the numerator collapses to one weighted
    tree-reduce because the deltas are pre-zeroed, and the denominator
    factors through the (few) distinct masks:
        num = sum_c w_c * delta_c
        den = sum_k (sum_{c: idx_c=k} w_c) * bank_k

    Expressed as the one-shard case of the hierarchical pipeline:
    `partial_sums` over the whole cohort, then `combine_partials` — the
    sharded executor (fl/shard_fleet.py) runs the same two functions with a
    psum in between.
    """
    k = jax.tree.leaves(mask_bank)[0].shape[0]
    num, w_per_mask = partial_sums(stacked_deltas, weights, mask_idx, k)
    return combine_partials(global_params, num, w_per_mask, mask_bank)


def staleness_scale(staleness, exponent):
    """Per-arrival staleness discount for buffered async FedAvg, normalized
    so a uniformly-stale buffer degenerates to plain masked FedAvg.

        scale_i = (1 + s_i)^(-a) / max_j (1 + s_j)^(-a)

    s_i is the number of server versions that advanced between client i's
    dispatch and its arrival (0 = trained on current params); `a` is the
    polynomial discount exponent (FedBuff's s^(-a) family, shifted so s=0
    is well-defined). The max-normalization gives two exact identities the
    async tests pin bitwise:

      * all-fresh buffer (s == 0):    (1+0)^(-a) == 1.0 and x/1.0 == x, so
        every scale is exactly 1.0 — async == sync aggregation;
      * uniformly-stale buffer:       x/x == 1.0 exactly in IEEE754, so a
        buffer where everyone is equally late is NOT down-weighted into a
        vanishing update — relative freshness is what matters.
    """
    s = jnp.asarray(staleness, jnp.float32)
    a = jnp.asarray(exponent, jnp.float32)
    raw = (1.0 + s) ** (-a)
    return raw / jnp.max(raw)


@jax.jit
def aggregate_buffered(global_params, stacked_deltas, weights,
                       mask_bank, mask_idx, staleness, exponent):
    """`aggregate_stacked` for an async arrival buffer (fl/async_rounds.py):
    identical masked-FedAvg pipeline (`partial_sums` -> `combine_partials`),
    with each arrival's sample-count weight scaled by `staleness_scale`
    before BOTH the numerator and the per-mask denominator — a stale
    straggler's coordinates are discounted consistently, so coordinates
    only it trained still average to its (discounted) delta rather than
    shrinking toward zero. With zero staleness everywhere the scaled
    weights equal `weights` bitwise and this is `aggregate_stacked`."""
    w = weights.astype(jnp.float32) * staleness_scale(staleness, exponent)
    k = jax.tree.leaves(mask_bank)[0].shape[0]
    num, w_per_mask = partial_sums(stacked_deltas, w, mask_idx, k)
    return combine_partials(global_params, num, w_per_mask, mask_bank)


def aggregate(global_params, updates: Sequence[ClientUpdate]):
    """Participation-weighted FedAvg."""
    num = jax.tree.map(jnp.zeros_like, global_params)
    den = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                       global_params)
    for u in updates:
        w = float(u.n_samples)
        if u.mask is None:
            num = jax.tree.map(lambda a, d: a + w * d.astype(a.dtype),
                               num, u.delta)
            den = jax.tree.map(lambda a: a + w, den)
        else:
            num = jax.tree.map(
                lambda a, d, m: a + (w * m * d).astype(a.dtype),
                num, u.delta, u.mask)
            den = jax.tree.map(lambda a, m: a + w * m, den, u.mask)
    return jax.tree.map(
        lambda p, n, d: p + jnp.where(d > 0, n / jnp.maximum(d, 1e-12),
                                      0.0).astype(p.dtype),
        global_params, num, den)
