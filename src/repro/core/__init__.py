from repro.core.aggregate import ClientUpdate, aggregate
from repro.core.dropout import DropoutPolicy
from repro.core.fluid import FluidConfig, FluidServer
