from repro.core.aggregate import ClientUpdate, aggregate
from repro.core.dropout import (DropoutPolicy, available_policies, get_policy,
                                register_policy)
from repro.core.fluid import FluidConfig, FluidServer
from repro.core.maskbank import MaskBank
