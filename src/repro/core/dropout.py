"""Sub-model neuron-selection policies: Random / Ordered / Invariant.

Every policy maps (group, dropout rate r) -> kept-neuron index array.
r in (0, 1] is the *kept* fraction (sub-model size as a fraction of the
global model, matching the paper's Table 2 convention).

Invariant selection (paper §4/§5): drop the neurons most agreed-invariant by
the non-straggler majority — ranked by (majority vote count, then lowest
historical update magnitude) — never dropping more than the target count.
An EMA of stats across calibration steps implements the paper's
"consistently fall below the threshold over multiple epochs" preference.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import invariant as inv


def keep_count(size: int, r: float, minimum: int = 1) -> int:
    return max(minimum, int(round(size * r)))


def random_keep(rng: np.random.RandomState, size: int, r: float) -> np.ndarray:
    k = keep_count(size, r)
    return np.sort(rng.choice(size, size=k, replace=False))


def ordered_keep(size: int, r: float) -> np.ndarray:
    """FjORD Ordered Dropout: keep the left-most k neurons."""
    return np.arange(keep_count(size, r))


def invariant_keep(votes: np.ndarray, stats: np.ndarray, r: float
                   ) -> np.ndarray:
    """votes: (#clients flagging invariant) per neuron; stats: mean update."""
    size = votes.shape[0]
    k = keep_count(size, r)
    n_drop = size - k
    # drop order: most votes first, then smallest mean update
    order = np.lexsort((stats, -votes))
    dropped = order[:n_drop]
    keep = np.setdiff1d(np.arange(size), dropped)
    return np.sort(keep)


@dataclass
class DropoutPolicy:
    """Stateful selector. method in {random, ordered, invariant}."""
    method: str
    unit_specs: Sequence[dict]
    seed: int = 0
    ema_decay: float = 0.5
    _rng: np.random.RandomState = field(init=False, repr=False)
    _ema_stats: Optional[Dict[str, np.ndarray]] = field(default=None,
                                                        repr=False)
    _votes: Optional[Dict[str, np.ndarray]] = field(default=None, repr=False)

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    # ------------------------------------------------------------------ state
    def observe(self, per_client_stats, th: float):
        """Feed this calibration step's non-straggler stats (invariant only)."""
        if self.method != "invariant":
            return
        votes = inv.invariant_counts(per_client_stats, th)
        means = inv.mean_stats(per_client_stats)
        if self._ema_stats is None:
            self._ema_stats, self._votes = means, {
                k: v.astype(np.float64) for k, v in votes.items()}
        else:
            a = self.ema_decay
            self._ema_stats = {k: a * self._ema_stats[k] + (1 - a) * means[k]
                               for k in means}
            self._votes = {k: a * self._votes[k] + (1 - a) * votes[k]
                           for k in votes}

    # -------------------------------------------------------------- selection
    def keep_map(self, r: float) -> Dict[str, np.ndarray]:
        """Kept indices per group for sub-model size r."""
        out = {}
        for g in self.unit_specs:
            name, size = g["name"], g["size"]
            if r >= 1.0:
                out[name] = np.arange(size)
            elif self.method == "random":
                out[name] = random_keep(self._rng, size, r)
            elif self.method == "ordered":
                out[name] = ordered_keep(size, r)
            elif self.method == "invariant":
                if self._votes is None:   # no stats yet: fall back to ordered
                    out[name] = ordered_keep(size, r)
                else:
                    out[name] = invariant_keep(self._votes[name],
                                               self._ema_stats[name], r)
            else:
                raise ValueError(self.method)
        return out
