"""Sub-model neuron-selection policies: Random / Ordered / Invariant.

Every policy maps (group, dropout rate r) -> kept-neuron index array.
r in (0, 1] is the *kept* fraction (sub-model size as a fraction of the
global model, matching the paper's Table 2 convention).

Policies live in a registry (``get_policy`` / ``register_policy``) so new
selection strategies (FedDHAD-style adaptive dropout, CLIP client-side
pruning, ...) plug in without touching the FL loop or the serving engine —
both resolve policies by name through the same table.

Invariant selection (paper §4/§5): drop the neurons most agreed-invariant by
the non-straggler majority — ranked by (majority vote count, then lowest
historical update magnitude) — never dropping more than the target count.
An EMA of stats across calibration steps implements the paper's
"consistently fall below the threshold over multiple epochs" preference.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Type

import numpy as np

from repro.core import invariant as inv


def keep_count(size: int, r: float, minimum: int = 1) -> int:
    return max(minimum, int(round(size * r)))


def random_keep(rng: np.random.RandomState, size: int, r: float) -> np.ndarray:
    k = keep_count(size, r)
    return np.sort(rng.choice(size, size=k, replace=False))


def ordered_keep(size: int, r: float) -> np.ndarray:
    """FjORD Ordered Dropout: keep the left-most k neurons."""
    return np.arange(keep_count(size, r))


def invariant_keep(votes: np.ndarray, stats: np.ndarray, r: float
                   ) -> np.ndarray:
    """votes: (#clients flagging invariant) per neuron; stats: mean update."""
    size = votes.shape[0]
    k = keep_count(size, r)
    n_drop = size - k
    # drop order: most votes first, then smallest mean update
    order = np.lexsort((stats, -votes))
    dropped = order[:n_drop]
    keep = np.setdiff1d(np.arange(size), dropped)
    return np.sort(keep)


# ---------------------------------------------------------------------------
# policy registry

_REGISTRY: Dict[str, Type["BasePolicy"]] = {}


def register_policy(name: str):
    """Class decorator: make a BasePolicy subclass resolvable by name."""
    def deco(cls):
        cls.method = name          # back-compat attribute (was a dataclass field)
        _REGISTRY[name] = cls
        return cls
    return deco


def available_policies() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_policy(name: str, unit_specs: Sequence[dict], seed: int = 0,
               **kw) -> "BasePolicy":
    """Instantiate a registered policy; extra kwargs are filtered to the
    policy's own fields (e.g. ema_decay only applies to 'invariant')."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown dropout policy {name!r}; "
                         f"available: {available_policies()}") from None
    names = {f.name for f in dataclasses.fields(cls)}
    return cls(unit_specs=unit_specs, seed=seed,
               **{k: v for k, v in kw.items() if k in names})


@dataclass
class BasePolicy:
    """Stateful selector over unit-spec'd neuron groups."""
    unit_specs: Sequence[dict]
    seed: int = 0
    _rng: np.random.RandomState = field(init=False, repr=False)

    def __post_init__(self):
        self._rng = np.random.RandomState(self.seed)

    # ------------------------------------------------------------------ state
    def observe(self, per_client_stats, th: float):
        """Feed this calibration step's non-straggler stats (no-op unless the
        policy is history-driven)."""

    # -------------------------------------------------------------- selection
    def keep(self, name: str, size: int, r: float) -> np.ndarray:
        raise NotImplementedError

    def keep_map(self, r: float) -> Dict[str, np.ndarray]:
        """Kept indices per group for sub-model size r."""
        out = {}
        for g in self.unit_specs:
            name, size = g["name"], g["size"]
            out[name] = (np.arange(size) if r >= 1.0
                         else self.keep(name, size, r))
        return out


@register_policy("random")
@dataclass
class RandomPolicy(BasePolicy):
    def keep(self, name, size, r):
        return random_keep(self._rng, size, r)


@register_policy("ordered")
@dataclass
class OrderedPolicy(BasePolicy):
    def keep(self, name, size, r):
        return ordered_keep(size, r)


@register_policy("invariant")
@dataclass
class InvariantPolicy(BasePolicy):
    ema_decay: float = 0.5
    _ema_stats: Optional[Dict[str, np.ndarray]] = field(default=None,
                                                        repr=False)
    _votes: Optional[Dict[str, np.ndarray]] = field(default=None, repr=False)

    def observe(self, per_client_stats, th: float):
        votes = inv.invariant_counts(per_client_stats, th)
        means = inv.mean_stats(per_client_stats)
        if self._ema_stats is None:
            self._ema_stats, self._votes = means, {
                k: v.astype(np.float64) for k, v in votes.items()}
        else:
            a = self.ema_decay
            self._ema_stats = {k: a * self._ema_stats[k] + (1 - a) * means[k]
                               for k in means}
            self._votes = {k: a * self._votes[k] + (1 - a) * votes[k]
                           for k in votes}

    def keep(self, name, size, r):
        if self._votes is None:       # no stats yet: fall back to ordered
            return ordered_keep(size, r)
        return invariant_keep(self._votes[name], self._ema_stats[name], r)


def DropoutPolicy(method: str, unit_specs: Sequence[dict], seed: int = 0,
                  **kw) -> BasePolicy:
    """Back-compat constructor-shaped alias for get_policy()."""
    return get_policy(method, unit_specs, seed=seed, **kw)
