"""Deduplicated mask banks — the storage layer of the "mask is data" idiom.

Both execution engines (fl/fleet.py training cohorts, launch/serving.py
decode batches) stack 0/1 masks into a bank of K *distinct* rows and carry a
per-client / per-request int32 index into it, so mask memory scales with the
number of distinct sub-models, not the population size, and the compiled
program sees one fixed bank shape.

Two usage modes:

  * capacity=None (fleet): the bank holds exactly the rows added; callers
    rebuild it when the keep-maps move (calibration steps), so K tracks the
    current number of distinct sub-models.
  * capacity=K (serving): ``stacked()`` always returns K rows — unused tail
    rows repeat row 0 (the all-ones full model) — so the bank's shape is a
    compile-time constant and admitting a request with a never-seen mask can
    NOT trigger a recompile of the decode program. When full, rows not
    referenced by any live request are evicted in place.

Row 0 is always the caller-supplied all-ones mask: index 0 == full model.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, Iterable, List, Optional

import jax
import jax.numpy as jnp

FULL_MODEL = "__full__"      # reserved fingerprint of row 0


class MaskBank:
    def __init__(self, ones_row, capacity: Optional[int] = None):
        if capacity is not None and capacity < 2:
            raise ValueError("capacity must allow row 0 plus one sub-model")
        self.capacity = capacity
        self._rows: List = [ones_row]
        self._fp_of_row: List[Hashable] = [FULL_MODEL]
        self._row_of_fp: Dict[Hashable, int] = {FULL_MODEL: 0}
        self._stacked = None

    def __len__(self) -> int:
        return len(self._rows)

    def row(self, i: int):
        """Host-side mask pytree stored at row i."""
        return self._rows[i]

    def row_for(self, fp: Hashable, build: Callable[[], object],
                in_use: Iterable[int] = ()) -> int:
        """Bank row holding the mask fingerprinted ``fp``; built via
        ``build()`` on a miss. ``in_use`` rows are protected from eviction."""
        got = self._row_of_fp.get(fp)
        if got is not None:
            return got
        if self.capacity is not None and len(self._rows) >= self.capacity:
            return self._replace(self._evictable(in_use), fp, build)
        self._rows.append(build())
        self._fp_of_row.append(fp)
        self._row_of_fp[fp] = len(self._rows) - 1
        self._stacked = None
        return len(self._rows) - 1

    def _evictable(self, in_use: Iterable[int]) -> int:
        live = set(in_use) | {0}
        for r in range(1, len(self._rows)):
            if r not in live:
                return r
        raise RuntimeError(
            f"mask bank full: all {self.capacity} rows referenced by live "
            "requests — raise bank capacity or drain the batch first")

    def _replace(self, victim: int, fp, build) -> int:
        del self._row_of_fp[self._fp_of_row[victim]]
        self._rows[victim] = build()
        self._fp_of_row[victim] = fp
        self._row_of_fp[fp] = victim
        self._stacked = None
        return victim

    def stacked(self):
        """Device bank: pytree with (K, ...) leaves. With a capacity set,
        K == capacity always (tail padded with row 0), so every call yields
        the same shapes and downstream jits never re-specialize."""
        if self._stacked is None:
            rows = list(self._rows)
            if self.capacity is not None:
                rows += [self._rows[0]] * (self.capacity - len(rows))
            self._stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *rows)
        return self._stacked
