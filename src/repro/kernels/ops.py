"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real
TPU — the kernels are written for TPU (pl.pallas_call + BlockSpec VMEM
tiling) and validated in interpret mode against ref.py oracles.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.decode_gqa import decode_gqa as _decode_gqa
from repro.kernels.invariant_stats import invariant_stats as _invariant_stats
from repro.kernels.masked_ffn import masked_ffn as _masked_ffn
from repro.kernels.rwkv_chunk import rwkv_chunk_scan as _rwkv_chunk_scan

BLOCK_NEURONS = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def invariant_stats(w0, w1, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _invariant_stats(w0, w1, **kw)


def masked_ffn(x, w_in, w_out, block_mask, w_gate=None, act="silu", **kw):
    kw.setdefault("interpret", _default_interpret())
    return _masked_ffn(x, w_in, w_out, block_mask, w_gate=w_gate, act=act,
                       **kw)


def decode_gqa(q, k, v, lengths, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _decode_gqa(q, k, v, lengths, **kw)


def rwkv_chunk_scan(r, k, v, logw, u, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _rwkv_chunk_scan(r, k, v, logw, u, **kw)


def neuron_mask_to_block_mask(mask: np.ndarray) -> np.ndarray:
    """Per-neuron 0/1 mask (F,) -> per-128-block mask (F//128,).
    A block survives if ANY of its neurons survives (conservative)."""
    F = mask.shape[0]
    assert F % BLOCK_NEURONS == 0
    return (mask.reshape(F // BLOCK_NEURONS, BLOCK_NEURONS).max(axis=1) > 0
            ).astype(np.int32)
