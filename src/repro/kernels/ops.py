"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real
TPU — the kernels are written for TPU (pl.pallas_call + BlockSpec VMEM
tiling) and validated in interpret mode against ref.py oracles.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.decode_gqa import decode_gqa as _decode_gqa
from repro.kernels.invariant_stats import invariant_stats as _invariant_stats
from repro.kernels.masked_attn import masked_attention as _masked_attention
from repro.kernels.masked_attn import masked_head_merge as _masked_head_merge
from repro.kernels.masked_attn import masked_head_proj as _masked_head_proj
from repro.kernels.masked_ffn import masked_ffn as _masked_ffn
from repro.kernels.masked_ffn import masked_ffn_batch as _masked_ffn_batch
from repro.kernels.rwkv_chunk import rwkv_chunk_scan as _rwkv_chunk_scan

BLOCK_NEURONS = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def invariant_stats(w0, w1, **kw):
    """Per-column relative update norm ||dW_col|| / (||W0_col|| + eps).

    w0, w1: (d_in, n) same shape/dtype. Returns (n,) fp32 — the per-neuron
    invariance statistic of DESIGN.md and core/invariant.py, fused into one
    Pallas reduction. Forward-only (server-side calibration).
    Oracle: ref.invariant_stats_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _invariant_stats(w0, w1, **kw)


def masked_ffn(x, w_in, w_out, block_mask, w_gate=None, act="silu", **kw):
    """Block-masked FFN, differentiable (DESIGN.md §10).

    y = act-FFN(x) with 128-neuron hidden blocks dropped per `block_mask`
    ((F//128,) 0/1): dropped blocks are *skipped*, forward and backward
    (custom_vjp; dropped-block dW is exactly zero). x: (M, d);
    w_in/(w_gate): (d, F); w_out: (F, d); F must be 128-aligned (ValueError
    otherwise). act in {relu, relu2, gelu, silu}; w_gate enables the gated
    (SwiGLU-style) form. Oracle: ref.masked_ffn_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _masked_ffn(x, w_in, w_out, block_mask, w_gate=w_gate, act=act,
                       **kw)


def masked_ffn_batch(x, w_in, w_out, row_mask, w_gate=None, act="silu", **kw):
    """Per-row-masked FFN, differentiable (DESIGN.md §10).

    Like masked_ffn but each row of x carries its own (F,) neuron mask
    (row_mask: (M, F) 0/1) — the serving/fleet form where one batch mixes
    sub-model sizes. A tile is skipped only when *every* row in the m-block
    drops the whole f-block (scalar-prefetch OR-mask); kept tiles apply the
    exact per-row mask. Oracle: ref.masked_ffn_batch_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _masked_ffn_batch(x, w_in, w_out, row_mask, w_gate=w_gate,
                             act=act, **kw)


def masked_head_proj(x, w, head_mask, **kw):
    """Head-masked input projection x @ w, differentiable (DESIGN.md §10).

    w: (d_in, H*hd) with heads laid out unit-major (head slow, head-dim
    fast); head_mask: (H,) 0/1. Dropped heads' output slabs are zeroed and
    their tiles skipped, forward and backward (dropped-head dW slab exactly
    zero). H must divide w.shape[1] evenly. Oracle: ref.masked_head_proj_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _masked_head_proj(x, w, head_mask, **kw)


def masked_head_merge(a, w, head_mask, **kw):
    """Head-masked output merge a @ w, differentiable (DESIGN.md §10).

    a: (M, H*hd) per-head context (unit-major); w: (H*hd, d_out);
    head_mask: (H,) 0/1. Dropped heads' row slabs of w are skipped — the
    dual of masked_head_proj, closing the head's consumer set.
    Oracle: ref.masked_head_merge_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _masked_head_merge(a, w, head_mask, **kw)


def masked_attention(x, wq, wk, wv, wo, head_mask, n_heads, **kw):
    """Head-masked causal MHA, differentiable (DESIGN.md §10).

    x: (B, S, d); wq/wk/wv: (d, H*hd); wo: (H*hd, d); head_mask: (H,) 0/1
    with n_heads == H. Kernel projections (dropped-head tiles skipped) →
    dense jnp causal softmax → kernel merge; the VJP composes the pieces'.
    Dropped heads contribute exact zeros end to end.
    Oracle: ref.masked_attention_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _masked_attention(x, wq, wk, wv, wo, head_mask, n_heads=n_heads,
                             **kw)


def decode_gqa(q, k, v, lengths, **kw):
    """Flash-decode grouped-query attention over a ragged KV cache.

    q: (B, H, hd); k/v: (B, C, KV, hd); lengths: (B,) valid prefix per
    batch row. Returns (B, H, hd). Forward-only (serving path; DESIGN.md
    §9.5). Oracle: ref.decode_gqa_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _decode_gqa(q, k, v, lengths, **kw)


def rwkv_chunk_scan(r, k, v, logw, u, **kw):
    """Chunked RWKV-6 linear-attention recurrence.

    r/k/v/logw: (B, S, H, N); u: (H, N). Returns (y (B,S,H,N) fp32,
    final state (B,H,N,N) fp32). Forward-only (serving path).
    Oracle: ref.rwkv_chunk_scan_ref."""
    kw.setdefault("interpret", _default_interpret())
    return _rwkv_chunk_scan(r, k, v, logw, u, **kw)


def neuron_mask_to_block_mask(mask: np.ndarray) -> np.ndarray:
    """Per-neuron 0/1 mask (F,) -> per-128-block mask (F//128,).
    A block survives if ANY of its neurons survives (conservative)."""
    F = mask.shape[0]
    assert F % BLOCK_NEURONS == 0
    return (mask.reshape(F // BLOCK_NEURONS, BLOCK_NEURONS).max(axis=1) > 0
            ).astype(np.int32)
