"""Pallas TPU kernel: fused RWKV-6 chunked linear-attention forward.

§Perf hillclimb 2 showed the pure-XLA chunked formulation is ~27× from the
compute roofline because the (c, c, N) decay tensor makes multiple HBM
round-trips. This kernel keeps the whole chunk working set — decay
cumsums, the D tensor, scores, and the (N, N) recurrent state — resident in
VMEM: HBM traffic is one read of r/k/v/logw and one write of y per token,
plus the final state. Recurrence (per head, head dim N):

  S_t = diag(w_t) S_{t-1} + k_t v_t^T
  y_t = r_t^T S_{t-1} + (r_t . (u ⊙ k_t)) v_t

Grid: (B*H, S/c); the state lives in fp32 VMEM scratch carried across the
chunk dimension (innermost), re-initialized at chunk 0. All decay products
are exp(sum-of-log differences) ≤ 0 — overflow-free at any chunk size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_ref,
            *, n_chunks, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)            # (c, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    logw = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :]                                      # (N,)
    S0 = state_ref[...]                                  # (N, N)

    l_inc = jnp.cumsum(logw, axis=0)
    l_exc = l_inc - logw
    l_tot = l_inc[-1:]

    # inter-chunk
    y = jnp.dot(r * jnp.exp(l_exc), S0,
                preferred_element_type=jnp.float32)       # (c, N)

    # intra-chunk: D[t,j,n] = exp(l_exc[t,n] - l_inc[j,n]), j < t
    dlog = l_exc[:, None, :] - l_inc[None, :, :]          # (c, c, N)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    p = r[:, None, :] * k[None, :, :] * jnp.exp(dlog)
    scores = jnp.where(tri, p.sum(axis=-1), 0.0)          # (c, c)
    y = y + jnp.dot(scores, v, preferred_element_type=jnp.float32)

    # diagonal bonus
    diag = jnp.sum(r * (u[None, :] * k), axis=-1, keepdims=True)
    y = y + diag * v

    # state update
    k_hat = k * jnp.exp(l_tot - l_inc)
    state_ref[...] = (jnp.exp(l_tot).T * S0
                      + jnp.dot(k_hat.T, v,
                                preferred_element_type=jnp.float32))

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_out_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_chunk_scan(r, k, v, logw, u, *, chunk: int = 64,
                    interpret: bool = True):
    """r,k,v: (B,S,H,N); logw: (B,S,H,N) fp32 (log decay, < 0); u: (H,N).
    Returns (y: (B,S,H,N) fp32, state: (B,H,N,N) fp32)."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (B * H, S // chunk)

    def im(bh, ci):
        return (bh // H, ci, bh % H, 0)

    y, state = pl.pallas_call(
        functools.partial(_kernel, n_chunks=grid[1], chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), im),
            pl.BlockSpec((1, chunk, 1, N), im),
            pl.BlockSpec((1, chunk, 1, N), im),
            pl.BlockSpec((1, chunk, 1, N), im),
            pl.BlockSpec((1, N), lambda bh, ci: (bh % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, N), im),
            pl.BlockSpec((1, 1, N, N), lambda bh, ci: (bh // H, bh % H,
                                                       0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u.astype(jnp.float32))
    return y, state
