"""Pallas TPU kernel: per-neuron relative-update statistic (FLuID core).

For a weight matrix pair (W0, W1) of shape (d_in, n) where column j holds
neuron j's fan-in weights, computes

    stat[j] = ||W1[:,j] - W0[:,j]||_2 / (||W0[:,j]||_2 + eps)

— the invariant-dropout statistic of Algorithm 1 (norm form, see
core/invariant.py). The server runs this over every layer at every
calibration step, so it is the framework's recurring server-side hot spot.

Tiling: grid (n_blocks, d_blocks) with the reduction dim innermost; partial
sums accumulate in fp32 VMEM scratch and the final sqrt/div runs on the last
reduction step. Block shapes are MXU/VPU aligned (128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EPS = 1e-8


def _kernel(w0_ref, w1_ref, out_ref, num_ref, den_ref, *, n_d_blocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    w0 = w0_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    d = w1 - w0
    num_ref[...] += jnp.sum(d * d, axis=0, keepdims=True)
    den_ref[...] += jnp.sum(w0 * w0, axis=0, keepdims=True)

    @pl.when(j == n_d_blocks - 1)
    def _finalize():
        out_ref[...] = (jnp.sqrt(num_ref[...])
                        / (jnp.sqrt(den_ref[...]) + EPS))


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def invariant_stats(w0, w1, *, block_n: int = 128, block_d: int = 256,
                    interpret: bool = True):
    """w0, w1: (d_in, n). Returns (n,) float32 per-neuron stat."""
    d_in, n = w0.shape
    assert w0.shape == w1.shape
    block_n = min(block_n, n)
    block_d = min(block_d, d_in)
    pad_n = (-n) % block_n
    pad_d = (-d_in) % block_d
    if pad_n or pad_d:
        w0 = jnp.pad(w0, ((0, pad_d), (0, pad_n)))
        w1 = jnp.pad(w1, ((0, pad_d), (0, pad_n)))
    dP, nP = w0.shape
    grid = (nP // block_n, dP // block_d)

    out = pl.pallas_call(
        functools.partial(_kernel, n_d_blocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, block_n), lambda i, j: (j, i)),
            pl.BlockSpec((block_d, block_n), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nP), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_n), jnp.float32),
                        pltpu.VMEM((1, block_n), jnp.float32)],
        interpret=interpret,
    )(w0, w1)
    return out[0, :n]
