"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

The serving hot spot for decode_32k / long_500k: one query token per
sequence attends over a C-deep cache. Flash-decoding style online softmax:
grid (B, C_blocks), fp32 running (max, sum, acc) in VMEM scratch, per-block
validity from prefix lengths (scalar prefetch, drives no control flow but
masks padded slots). GQA handled by reshaping H = KV * G inside the block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, n_c_blocks, block_c, kv_heads, scale):
    b = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (H, hd)
    H, hd = q.shape
    G = H // kv_heads
    k = k_ref[0].astype(jnp.float32)                    # (bc, KV, hd)
    v = v_ref[0].astype(jnp.float32)

    qg = q.reshape(kv_heads, G, hd)
    s = jnp.einsum("kgd,ckd->kgc", qg, k) * scale       # (KV, G, bc)
    pos = c * block_c + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_c),
                                                 2)
    s = jnp.where(pos < len_ref[b], s, NEG)
    s = s.reshape(H, block_c)

    m_prev = m_ref[...]                                 # (H, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (H, bc)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    pv = jnp.einsum("kgc,ckd->kgd", p.reshape(kv_heads, G, block_c),
                    v).reshape(H, hd)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(c == n_c_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def decode_gqa(q, k, v, lengths, *, block_c: int = 512,
               interpret: bool = True):
    """q: (B,H,hd); k,v: (B,C,KV,hd); lengths: (B,) valid prefix.
    Returns (B,H,hd) in q.dtype."""
    B, H, hd = q.shape
    _, C, KV, _ = k.shape
    assert H % KV == 0
    block_c = min(block_c, C)
    pad_c = (-C) % block_c
    if pad_c:
        k = jnp.pad(k, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_c), (0, 0), (0, 0)))
    CP = k.shape[1]
    grid = (B, CP // block_c)
    scale = 1.0 / (hd ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, n_c_blocks=grid[1], block_c=block_c,
                          kv_heads=KV, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, hd), lambda b, c, L: (b, 0, 0)),
                pl.BlockSpec((1, block_c, KV, hd),
                             lambda b, c, L: (b, c, 0, 0)),
                pl.BlockSpec((1, block_c, KV, hd),
                             lambda b, c, L: (b, c, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, hd), lambda b, c, L: (b, 0, 0)),
            scratch_shapes=[pltpu.VMEM((H, hd), jnp.float32),
                            pltpu.VMEM((H, 1), jnp.float32),
                            pltpu.VMEM((H, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
    return out
