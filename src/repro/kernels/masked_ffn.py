"""Pallas TPU kernels: block-masked FFN, forward AND backward (DESIGN.md §2, §10).

Computes   y = (act(x @ W_in) [* act(x @ W_gate)]) ⊙ mask) @ W_out
where the neuron mask has 128-block granularity (DESIGN.md §2: the
TPU-native adaptation of neuron dropout — dropping aligned blocks keeps
every surviving matmul tile MXU-shaped). Dropped blocks SKIP both matmuls
via ``pl.when``, so a straggler running a sub-model of size r does ~r of the
FFN FLOPs *without re-compiling per mask* — the mask is a runtime input.

Both public entry points (`masked_ffn`, `masked_ffn_batch`) are wrapped in
``jax.custom_vjp`` with Pallas backward kernels that exploit the same
invariant-dropout structure (DESIGN.md §10):

  * dL/dW_in, dL/dW_gate columns and dL/dW_out rows of a dropped block are
    zero **by construction** (the forward never touched them), so the dW
    kernel only visits kept tiles and writes zeros elsewhere.
  * dL/dx only accumulates contributions from kept blocks, so the dx kernel
    skips dropped tiles exactly like the forward.

Both backward kernels recompute the hidden pre-activations from the saved
inputs (no activation residuals — the memory-light "recompute" policy), and
route tile skipping through the identical scalar-prefetch mask path as the
forward, so a rate-r sub-model pays ~r of the FLOPs in the *whole* train
step, not just inference.

Grid layout: forward and dx use (m_blocks, f_blocks) with f (the masked
hidden dim) innermost so the fp32 accumulator tile in VMEM is revisited;
the dW kernel transposes the grid to (f_blocks, m_blocks) so each weight
tile's accumulator sees its m-visits consecutively. Block masks are
scalar-prefetch operands (SMEM) because they drive control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_NEURONS = 128

_ACTS = {"relu": lambda h: jnp.maximum(h, 0.0),
         "relu2": lambda h: jnp.square(jnp.maximum(h, 0.0)),
         "gelu": jax.nn.gelu,
         "silu": jax.nn.silu}


def _dgelu(z):
    # derivative of jax.nn.gelu's default tanh approximation
    c = 0.7978845608028654            # sqrt(2/pi)
    u = c * (z + 0.044715 * z * z * z)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * z * z)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du


def _dsilu(z):
    s = jax.nn.sigmoid(z)
    return s * (1.0 + z * (1.0 - s))


_DACTS = {"relu": lambda z: (z > 0).astype(z.dtype),
          "relu2": lambda z: 2.0 * jnp.maximum(z, 0.0),
          "gelu": _dgelu,
          "silu": _dsilu}


# ---------------------------------------------------------------------------
# shape validation (the silent-dense footgun fix: reject mis-tiled inputs
# loudly instead of silently computing something block-misaligned)

def _validate(x, w_in, w_out, w_gate, mask, per_row: bool):
    if x.ndim != 2:
        raise ValueError(f"x must be (M, d), got shape {x.shape}")
    M, d = x.shape
    if w_in.ndim != 2 or w_in.shape[0] != d:
        raise ValueError(f"w_in must be (d={d}, F), got {w_in.shape}")
    F = w_in.shape[1]
    if F % BLOCK_NEURONS != 0:
        raise ValueError(
            f"masked FFN hidden dim F={F} must be a multiple of "
            f"BLOCK_NEURONS={BLOCK_NEURONS}; pad w_in/w_out (and the mask) "
            f"to 128 alignment — anything else would mis-tile the block "
            f"skip (DESIGN.md §10)")
    if w_out.shape != (F, d):
        raise ValueError(f"w_out must be (F={F}, d={d}), got {w_out.shape}")
    if w_gate is not None and w_gate.shape != (d, F):
        raise ValueError(f"w_gate must be (d={d}, F={F}), got {w_gate.shape}")
    if per_row:
        if mask.shape != (M, F):
            raise ValueError(
                f"row_mask must be (M={M}, F={F}) — one 0/1 neuron mask per "
                f"row of x — got {mask.shape}")
    else:
        if mask.shape != (F // BLOCK_NEURONS,):
            raise ValueError(
                f"block_mask must be (F//{BLOCK_NEURONS},) = "
                f"({F // BLOCK_NEURONS},) — one 0/1 entry per 128-neuron "
                f"block — got {mask.shape}. For neuron-granular masks use "
                f"masked_ffn_batch (per-row masks) instead")


# ---------------------------------------------------------------------------
# forward kernels (unchanged math; see module docstring)

def _fwd_kernel(mask_ref, x_ref, rm_ref, win_ref, wgate_ref, wout_ref,
                y_ref, acc_ref, *, n_f_blocks, act, per_row):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    keep = mask_ref[i * n_f_blocks + j] if per_row else mask_ref[j]

    @pl.when(keep > 0)
    def _block():
        x = x_ref[...]
        h = jnp.dot(x, win_ref[...], preferred_element_type=jnp.float32)
        if wgate_ref is not None:
            g = jnp.dot(x, wgate_ref[...], preferred_element_type=jnp.float32)
            h = act(g) * h
        else:
            h = act(h)
        if rm_ref is not None:
            h = h * rm_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.dot(h.astype(x.dtype), wout_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(j == n_f_blocks - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


# ---------------------------------------------------------------------------
# backward kernels
#
# Shared recompute helper: given the x / g tiles and the j-th weight blocks,
# produce (hm, dzh, dzg) where hm is the masked hidden activation tile and
# dzh / dzg are the cotangents of the pre-activations. All fp32.

def _bwd_core(x, g, rm, win, wgate, wout, act, dact):
    zh = jnp.dot(x, win, preferred_element_type=jnp.float32)
    ghm = jnp.dot(g, wout.T, preferred_element_type=jnp.float32)
    if rm is not None:
        rmf = rm.astype(jnp.float32)
        ghm = ghm * rmf
    if wgate is not None:
        zg = jnp.dot(x, wgate, preferred_element_type=jnp.float32)
        a = act(zg)
        hm = a * zh
        dzh = ghm * a
        dzg = ghm * zh * dact(zg)
    else:
        hm = act(zh)
        dzh = ghm * dact(zh)
        dzg = None
    if rm is not None:
        hm = hm * rmf
    return hm, dzh, dzg


def _dx_kernel(mask_ref, g_ref, x_ref, rm_ref, win_ref, wgate_ref, wout_ref,
               dx_ref, acc_ref, *, n_f_blocks, act, dact, per_row):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    keep = mask_ref[i * n_f_blocks + j] if per_row else mask_ref[j]

    @pl.when(keep > 0)
    def _block():
        rm = rm_ref[...] if rm_ref is not None else None
        wg = wgate_ref[...] if wgate_ref is not None else None
        _, dzh, dzg = _bwd_core(x_ref[...], g_ref[...], rm, win_ref[...],
                                wg, wout_ref[...], act, dact)
        acc_ref[...] += jnp.dot(dzh, win_ref[...].T,
                                preferred_element_type=jnp.float32)
        if wgate_ref is not None:
            acc_ref[...] += jnp.dot(dzg, wgate_ref[...].T,
                                    preferred_element_type=jnp.float32)

    @pl.when(j == n_f_blocks - 1)
    def _finalize():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def _dw_kernel(mask_ref, g_ref, x_ref, rm_ref, win_ref, wgate_ref, wout_ref,
               dwin_ref, dwout_ref, dwgate_ref,
               ain_ref, aout_ref, agate_ref, *, n_m_blocks, n_f_blocks,
               act, dact, per_row):
    j = pl.program_id(0)          # f block (outer: each dW tile is visited
    i = pl.program_id(1)          # m block (inner) for all its m-steps)

    @pl.when(i == 0)
    def _init():
        ain_ref[...] = jnp.zeros_like(ain_ref)
        aout_ref[...] = jnp.zeros_like(aout_ref)
        if agate_ref is not None:
            agate_ref[...] = jnp.zeros_like(agate_ref)

    keep = mask_ref[i * n_f_blocks + j] if per_row else mask_ref[j]

    @pl.when(keep > 0)
    def _block():
        x = x_ref[...]
        g = g_ref[...]
        rm = rm_ref[...] if rm_ref is not None else None
        wg = wgate_ref[...] if wgate_ref is not None else None
        hm, dzh, dzg = _bwd_core(x, g, rm, win_ref[...], wg, wout_ref[...],
                                 act, dact)
        ain_ref[...] += jnp.dot(x.T, dzh, preferred_element_type=jnp.float32)
        aout_ref[...] += jnp.dot(hm.T, g.astype(jnp.float32),
                                 preferred_element_type=jnp.float32)
        if agate_ref is not None:
            agate_ref[...] += jnp.dot(x.T, dzg,
                                      preferred_element_type=jnp.float32)

    @pl.when(i == n_m_blocks - 1)
    def _finalize():
        # dropped blocks: the accumulators were never touched => exact zeros,
        # the invariant-dropout structural guarantee of DESIGN.md §10.
        dwin_ref[...] = ain_ref[...].astype(dwin_ref.dtype)
        dwout_ref[...] = aout_ref[...].astype(dwout_ref.dtype)
        if dwgate_ref is not None:
            dwgate_ref[...] = agate_ref[...].astype(dwgate_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call assembly

def _adapt(kernel, has_rm, has_gate, n_fixed=3):
    """Inject None for the absent optional refs (row_mask / w_gate /
    dw_gate+its scratch) so one kernel body serves all variants."""
    def fn(*refs):
        it = iter(refs)
        head = [next(it) for _ in range(n_fixed)]          # mask, g?, x...
        rm = next(it) if has_rm else None
        win = next(it)
        wg = next(it) if has_gate else None
        wout = next(it)
        rest = list(it)
        return kernel(*head, rm, win, wg, wout, *rest)
    return fn


def _pad_rows(arr, block_m):
    pad = (-arr.shape[0]) % block_m
    if pad:
        arr = jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1))
    return arr


def _prefetch_mask(mask, M, F, block_m, per_row):
    """int32 tile-skip vector for scalar prefetch. per_row: OR-reduce over
    the rows of each (m, f) tile — a tile runs iff ANY row keeps ANY neuron
    of the block; flat layout [i * n_f + j]."""
    n_f = F // BLOCK_NEURONS
    if per_row:
        mp = _pad_rows(mask, block_m)
        grid_m = mp.shape[0] // block_m
        return (mp.reshape(grid_m, block_m, n_f, BLOCK_NEURONS)
                .max(axis=(1, 3)) > 0).astype(jnp.int32).reshape(-1)
    return (mask > 0).astype(jnp.int32)


def _io_specs(d, block_m, gated, per_row, with_g):
    """BlockSpecs for the (g?, x, rm?, w_in, w_gate?, w_out) operand tail
    shared by all three kernels (index maps in (i=m, j=f) grid order)."""
    specs = []
    if with_g:
        specs.append(pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)))
    specs.append(pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)))
    if per_row:
        specs.append(pl.BlockSpec((block_m, BLOCK_NEURONS),
                                  lambda i, j, m: (i, j)))
    specs.append(pl.BlockSpec((d, BLOCK_NEURONS), lambda i, j, m: (0, j)))
    if gated:
        specs.append(pl.BlockSpec((d, BLOCK_NEURONS), lambda i, j, m: (0, j)))
    specs.append(pl.BlockSpec((BLOCK_NEURONS, d), lambda i, j, m: (j, 0)))
    return specs


def _fwd_impl(x, w_in, w_out, w_gate, mask, *, act, block_m, interpret,
              per_row):
    M, d = x.shape
    F = w_in.shape[1]
    block_m = min(block_m, M)
    tmask = _prefetch_mask(mask, M, F, block_m, per_row)
    x = _pad_rows(x, block_m)
    MP = x.shape[0]
    n_f = F // BLOCK_NEURONS
    grid = (MP // block_m, n_f)

    args = [tmask, x]
    if per_row:
        args.append(_pad_rows(mask, block_m).astype(x.dtype))
    args.append(w_in)
    if w_gate is not None:
        args.append(w_gate)
    args.append(w_out)

    kernel = _adapt(functools.partial(_fwd_kernel, n_f_blocks=n_f,
                                      act=_ACTS[act], per_row=per_row),
                    has_rm=per_row, has_gate=w_gate is not None, n_fixed=2)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=_io_specs(d, block_m, w_gate is not None, per_row,
                               with_g=False),
            out_specs=pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)),
            scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((MP, d), x.dtype),
        interpret=interpret,
    )(*args)
    return y[:M]


def _dx_impl(gy, x, w_in, w_out, w_gate, mask, *, act, block_m, interpret,
             per_row):
    M, d = x.shape
    F = w_in.shape[1]
    block_m = min(block_m, M)
    tmask = _prefetch_mask(mask, M, F, block_m, per_row)
    gy = _pad_rows(gy, block_m)
    x = _pad_rows(x, block_m)
    MP = x.shape[0]
    n_f = F // BLOCK_NEURONS
    grid = (MP // block_m, n_f)

    args = [tmask, gy, x]
    if per_row:
        args.append(_pad_rows(mask, block_m).astype(x.dtype))
    args.append(w_in)
    if w_gate is not None:
        args.append(w_gate)
    args.append(w_out)

    kernel = _adapt(functools.partial(_dx_kernel, n_f_blocks=n_f,
                                      act=_ACTS[act], dact=_DACTS[act],
                                      per_row=per_row),
                    has_rm=per_row, has_gate=w_gate is not None, n_fixed=3)
    dx = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=_io_specs(d, block_m, w_gate is not None, per_row,
                               with_g=True),
            out_specs=pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)),
            scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((MP, d), x.dtype),
        interpret=interpret,
    )(*args)
    return dx[:M]


def _dw_impl(gy, x, w_in, w_out, w_gate, mask, *, act, block_m, interpret,
             per_row):
    M, d = x.shape
    F = w_in.shape[1]
    block_m = min(block_m, M)
    tmask = _prefetch_mask(mask, M, F, block_m, per_row)
    gy = _pad_rows(gy, block_m)
    x = _pad_rows(x, block_m)
    MP = x.shape[0]
    n_f = F // BLOCK_NEURONS
    gated = w_gate is not None
    grid = (n_f, MP // block_m)                      # f outer, m inner

    args = [tmask, gy, x]
    if per_row:
        args.append(_pad_rows(mask, block_m).astype(x.dtype))
    args.append(w_in)
    if gated:
        args.append(w_gate)
    args.append(w_out)

    # reuse the (i=m, j=f) index maps by swapping grid coordinates
    base = _io_specs(d, block_m, gated, per_row, with_g=True)
    in_specs = [pl.BlockSpec(s.block_shape,
                             functools.partial(
                                 lambda j, i, m, f=s.index_map: f(i, j, m)))
                for s in base]

    out_shapes = [jax.ShapeDtypeStruct((d, F), w_in.dtype),
                  jax.ShapeDtypeStruct((F, d), w_out.dtype)]
    out_specs = [pl.BlockSpec((d, BLOCK_NEURONS), lambda j, i, m: (0, j)),
                 pl.BlockSpec((BLOCK_NEURONS, d), lambda j, i, m: (j, 0))]
    scratch = [pltpu.VMEM((d, BLOCK_NEURONS), jnp.float32),
               pltpu.VMEM((BLOCK_NEURONS, d), jnp.float32)]
    if gated:
        out_shapes.append(jax.ShapeDtypeStruct((d, F), w_gate.dtype))
        out_specs.append(pl.BlockSpec((d, BLOCK_NEURONS),
                                      lambda j, i, m: (0, j)))
        scratch.append(pltpu.VMEM((d, BLOCK_NEURONS), jnp.float32))

    body = functools.partial(_dw_kernel, n_m_blocks=grid[1], n_f_blocks=n_f,
                             act=_ACTS[act], dact=_DACTS[act],
                             per_row=per_row)

    def kernel_fn(*refs):
        it = iter(refs)
        tm, g, xr = next(it), next(it), next(it)
        rm = next(it) if per_row else None
        win = next(it)
        wg = next(it) if gated else None
        wout = next(it)
        dwin, dwout = next(it), next(it)
        dwg = next(it) if gated else None
        ain, aout = next(it), next(it)
        ag = next(it) if gated else None
        return body(tm, g, xr, rm, win, wg, wout, dwin, dwout, dwg,
                    ain, aout, ag)

    out = pl.pallas_call(
        kernel_fn,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(*args)
    dwin, dwout = out[0], out[1]
    dwgate = out[2] if gated else None
    return dwin, dwout, dwgate


@functools.lru_cache(maxsize=None)
def _differentiable(act, block_m, interpret, per_row):
    """custom_vjp-wrapped masked FFN, cached per static config.

    The mask primal rides through the vjp as float32; its cotangent is a
    symbolic zero (the mask is sub-model structure, not a trained weight)."""
    kw = dict(act=act, block_m=block_m, interpret=interpret, per_row=per_row)

    @jax.custom_vjp
    def f(x, w_in, w_out, w_gate, mask):
        return _fwd_impl(x, w_in, w_out, w_gate, mask, **kw)

    def fwd(x, w_in, w_out, w_gate, mask):
        return (_fwd_impl(x, w_in, w_out, w_gate, mask, **kw),
                (x, w_in, w_out, w_gate, mask))

    def bwd(res, gy):
        x, w_in, w_out, w_gate, mask = res
        dx = _dx_impl(gy, x, w_in, w_out, w_gate, mask, **kw)
        dwin, dwout, dwgate = _dw_impl(gy, x, w_in, w_out, w_gate, mask, **kw)
        return dx, dwin, dwout, dwgate, jnp.zeros_like(mask)

    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# public entry points

@functools.partial(jax.jit, static_argnames=("act", "block_m", "interpret"))
def masked_ffn(x, w_in, w_out, block_mask, w_gate=None, *, act: str = "silu",
               block_m: int = 128, interpret: bool = True):
    """Block-masked FFN, differentiable (custom_vjp, Pallas backward).

    Shapes/dtypes: ``x`` (M, d) float32/bf16; ``w_in`` [, ``w_gate``]
    (d, F); ``w_out`` (F, d); returns (M, d) in ``x.dtype``.
    Mask granularity: ``block_mask`` is (F // 128,) 0/1 (int or float) —
    one entry per 128-neuron block; dropped blocks are skipped entirely in
    forward, dx, and dW (whose dropped tiles are exact zeros).
    Padding/alignment: F must be a multiple of 128 (ValueError otherwise —
    never a silent dense fallback); M is padded internally to ``block_m``.
    ``jax.grad`` through this function matches the dense ``mask ⊙ params``
    reference to fp32 tolerance (tests/test_kernel_grad.py)."""
    _validate(x, w_in, w_out, w_gate, block_mask, per_row=False)
    f = _differentiable(act, block_m, interpret, per_row=False)
    return f(x, w_in, w_out, w_gate, block_mask.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("act", "block_m", "interpret"))
def masked_ffn_batch(x, w_in, w_out, row_mask, w_gate=None, *,
                     act: str = "silu", block_m: int = 8,
                     interpret: bool = True):
    """Per-ROW-masked FFN, differentiable — the serving/fleet variant where
    each row of x carries its own sub-model mask.

    Shapes/dtypes: ``x`` (M, d); ``w_in`` [, ``w_gate``] (d, F); ``w_out``
    (F, d); ``row_mask`` (M, F) 0/1 (neuron-granular, any pattern — exact,
    not rounded to blocks). Returns (M, d) in ``x.dtype``.
    Padding/alignment: F must be a multiple of 128 (ValueError otherwise);
    M pads internally to ``block_m`` with zero mask rows.

    A tile (i, j) is skipped entirely only when NO row in m-block i keeps
    any neuron of f-block j (tile OR-mask, scalar-prefetch driven, same
    ``pl.when`` structure as ``masked_ffn``); surviving tiles apply the
    exact per-row mask to the hidden activations. With a homogeneous batch
    this degenerates to the block-skip kernel; with a mixed-rate batch the
    skip rate follows the UNION of the requests' kept sets per m-block —
    sorting requests by mask (launch/serving.py admits per-slot) recovers
    most of the single-mask savings. The backward kernels skip through the
    identical OR-mask, and within kept tiles the exact row mask zeroes the
    dropped neurons' cotangents, so dW of fully-dropped neurons is exactly
    zero (DESIGN.md §10)."""
    _validate(x, w_in, w_out, w_gate, row_mask, per_row=True)
    f = _differentiable(act, block_m, interpret, per_row=True)
    return f(x, w_in, w_out, w_gate, row_mask.astype(jnp.float32))
