"""Pallas TPU kernel: block-masked FFN forward (Invariant-Dropout sub-model).

Computes   y = (act(x @ W_in) [* act(x @ W_gate)]) ⊙ mask) @ W_out
where the neuron mask has 128-block granularity (DESIGN.md §2: the
TPU-native adaptation of neuron dropout — dropping aligned blocks keeps
every surviving matmul tile MXU-shaped). Dropped blocks SKIP both matmuls
via ``pl.when``, so a straggler running a sub-model of size r does ~r of the
FFN FLOPs *without re-compiling per mask* — the mask is a runtime input.

Grid: (m_blocks, f_blocks); f (the masked hidden dim) is innermost so the
fp32 accumulator tile in VMEM is revisited. The block mask is a
scalar-prefetch operand (SMEM) because it drives control flow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_NEURONS = 128


def _kernel(mask_ref, x_ref, win_ref, wgate_ref, wout_ref, y_ref, acc_ref,
            *, n_f_blocks, act):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[j] > 0)
    def _block():
        x = x_ref[...]
        h = jnp.dot(x, win_ref[...],
                    preferred_element_type=jnp.float32)
        if wgate_ref is not None:
            g = jnp.dot(x, wgate_ref[...],
                        preferred_element_type=jnp.float32)
            h = act(g) * h
        else:
            h = act(h)
        acc_ref[...] += jnp.dot(h.astype(x.dtype), wout_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(j == n_f_blocks - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


_ACTS = {"relu": lambda h: jnp.maximum(h, 0.0),
         "relu2": lambda h: jnp.square(jnp.maximum(h, 0.0)),
         "gelu": jax.nn.gelu,
         "silu": jax.nn.silu}


def _kernel_batch(tmask_ref, x_ref, mask_ref, win_ref, wgate_ref, wout_ref,
                  y_ref, acc_ref, *, n_f_blocks, act):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(tmask_ref[i * n_f_blocks + j] > 0)
    def _block():
        x = x_ref[...]
        h = jnp.dot(x, win_ref[...],
                    preferred_element_type=jnp.float32)
        if wgate_ref is not None:
            g = jnp.dot(x, wgate_ref[...],
                        preferred_element_type=jnp.float32)
            h = act(g) * h
        else:
            h = act(h)
        h = h * mask_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.dot(h.astype(x.dtype), wout_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(j == n_f_blocks - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act", "block_m", "interpret"))
def masked_ffn(x, w_in, w_out, block_mask, w_gate=None, *, act: str = "silu",
               block_m: int = 128, interpret: bool = True):
    """x: (M, d); w_in[, w_gate]: (d, F); w_out: (F, d);
    block_mask: (F // 128,) int32 (1 = keep block, 0 = dropped).
    Returns y: (M, d) in x.dtype. F must be a multiple of 128."""
    M, d = x.shape
    F = w_in.shape[1]
    assert F % BLOCK_NEURONS == 0 and block_mask.shape == (F // BLOCK_NEURONS,)
    block_m = min(block_m, M)
    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    MP = x.shape[0]
    grid = (MP // block_m, F // BLOCK_NEURONS)

    gate_specs = []
    args = [block_mask.astype(jnp.int32), x, w_in]
    if w_gate is not None:
        args.append(w_gate)
        gate_specs = [pl.BlockSpec((d, BLOCK_NEURONS), lambda i, j, m: (0, j))]
    args.append(w_out)

    kernel = functools.partial(
        _kernel, n_f_blocks=grid[1], act=_ACTS[act])
    if w_gate is None:
        kernel_fn = lambda m, xr, wi, wo, y, a: kernel(m, xr, wi, None, wo,
                                                       y, a)
    else:
        kernel_fn = kernel

    y = pl.pallas_call(
        kernel_fn,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)),
                pl.BlockSpec((d, BLOCK_NEURONS), lambda i, j, m: (0, j)),
                *gate_specs,
                pl.BlockSpec((BLOCK_NEURONS, d), lambda i, j, m: (j, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)),
            scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((MP, d), x.dtype),
        interpret=interpret,
    )(*args)
    return y[:M]


@functools.partial(jax.jit, static_argnames=("act", "block_m", "interpret"))
def masked_ffn_batch(x, w_in, w_out, row_mask, w_gate=None, *,
                     act: str = "silu", block_m: int = 8,
                     interpret: bool = True):
    """Per-ROW-masked FFN — the serving decode variant, where each row of x
    is a different request carrying its own sub-model mask.

    x: (M, d); w_in[, w_gate]: (d, F); w_out: (F, d); row_mask: (M, F) 0/1.
    Returns y: (M, d) in x.dtype. F must be a multiple of 128.

    A tile (i, j) is skipped entirely only when NO row in m-block i keeps
    any neuron of f-block j (tile_mask OR-reduce, scalar-prefetch driven,
    same ``pl.when`` structure as ``masked_ffn``); surviving tiles apply the
    exact per-row mask to the hidden activations. With a homogeneous decode
    batch this degenerates to the block-skip kernel; with a mixed-rate batch
    the skip rate follows the UNION of the requests' kept sets per m-block —
    sorting requests by mask (launch/serving.py admits per-slot) recovers
    most of the single-mask savings.
    """
    M, d = x.shape
    F = w_in.shape[1]
    assert F % BLOCK_NEURONS == 0 and row_mask.shape == (M, F), \
        (row_mask.shape, (M, F))
    block_m = min(block_m, M)
    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
        row_mask = jnp.pad(row_mask, ((0, pad_m), (0, 0)))
    MP = x.shape[0]
    n_f = F // BLOCK_NEURONS
    grid = (MP // block_m, n_f)

    # (m_blocks * f_blocks,) i32: does any row of m-block i touch f-block j?
    tile_mask = (row_mask.reshape(grid[0], block_m, n_f, BLOCK_NEURONS)
                 .max(axis=(1, 3)) > 0).astype(jnp.int32).reshape(-1)

    gate_specs = []
    args = [tile_mask, x, row_mask.astype(x.dtype), w_in]
    if w_gate is not None:
        args.append(w_gate)
        gate_specs = [pl.BlockSpec((d, BLOCK_NEURONS), lambda i, j, m: (0, j))]
    args.append(w_out)

    kernel = functools.partial(
        _kernel_batch, n_f_blocks=n_f, act=_ACTS[act])
    if w_gate is None:
        kernel_fn = lambda t, xr, mr, wi, wo, y, a: kernel(t, xr, mr, wi,
                                                           None, wo, y, a)
    else:
        kernel_fn = kernel

    y = pl.pallas_call(
        kernel_fn,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)),
                pl.BlockSpec((block_m, BLOCK_NEURONS),
                             lambda i, j, m: (i, j)),
                pl.BlockSpec((d, BLOCK_NEURONS), lambda i, j, m: (0, j)),
                *gate_specs,
                pl.BlockSpec((BLOCK_NEURONS, d), lambda i, j, m: (j, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, d), lambda i, j, m: (i, 0)),
            scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((MP, d), x.dtype),
        interpret=interpret,
    )(*args)
    return y[:M]
