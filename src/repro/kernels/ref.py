"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-8

_ACTS = {"relu": lambda h: jnp.maximum(h, 0.0),
         "relu2": lambda h: jnp.square(jnp.maximum(h, 0.0)),
         "gelu": jax.nn.gelu,
         "silu": jax.nn.silu}


def invariant_stats_ref(w0, w1):
    """(d_in, n) -> (n,) fp32: ||dW_col|| / (||W0_col|| + eps)."""
    w0 = w0.astype(jnp.float32)
    w1 = w1.astype(jnp.float32)
    num = jnp.sqrt(jnp.sum(jnp.square(w1 - w0), axis=0))
    den = jnp.sqrt(jnp.sum(jnp.square(w0), axis=0))
    return num / (den + EPS)


def masked_ffn_ref(x, w_in, w_out, block_mask, w_gate=None, act="silu"):
    """Block-masked FFN oracle: hidden activations multiplied by the
    128-expanded block mask before the output projection."""
    xf = x.astype(jnp.float32)
    h = xf @ w_in.astype(jnp.float32)
    if w_gate is not None:
        g = xf @ w_gate.astype(jnp.float32)
        h = _ACTS[act](g) * h
    else:
        h = _ACTS[act](h)
    F = w_in.shape[1]
    mask = jnp.repeat(block_mask.astype(jnp.float32), F // block_mask.shape[0])
    h = h * mask
    return (h @ w_out.astype(jnp.float32)).astype(x.dtype)


def masked_ffn_batch_ref(x, w_in, w_out, row_mask, w_gate=None, act="silu"):
    """Per-row-masked FFN oracle: hidden activations multiplied by each
    row's own (M, F) 0/1 neuron mask before the output projection."""
    xf = x.astype(jnp.float32)
    h = xf @ w_in.astype(jnp.float32)
    if w_gate is not None:
        g = xf @ w_gate.astype(jnp.float32)
        h = _ACTS[act](g) * h
    else:
        h = _ACTS[act](h)
    h = h * row_mask.astype(jnp.float32)
    return (h @ w_out.astype(jnp.float32)).astype(x.dtype)


def head_mask_expand(head_mask, dout):
    """(H,) head mask -> (dout,) per-column mask, head-dim fastest."""
    H = head_mask.shape[0]
    return jnp.repeat(head_mask.astype(jnp.float32), dout // H)


def masked_head_proj_ref(x, w, head_mask):
    """Dense oracle for masked_head_proj: x @ (w with dropped-head columns
    zeroed)."""
    m = head_mask_expand(head_mask, w.shape[1])
    return (x.astype(jnp.float32) @ (w.astype(jnp.float32) * m[None, :])
            ).astype(x.dtype)


def masked_head_merge_ref(a, w, head_mask):
    """Dense oracle for masked_head_merge: (a with dropped-head columns
    zeroed) @ w — equivalently w with dropped-head ROWS zeroed."""
    m = head_mask_expand(head_mask, a.shape[1])
    return ((a.astype(jnp.float32) * m[None, :]) @ w.astype(jnp.float32)
            ).astype(a.dtype)


def masked_attention_ref(x, wq, wk, wv, wo, head_mask, n_heads):
    """Dense causal MHA over head_mask ⊙ params (Q/K/V column head-slabs
    and O row head-slabs zeroed)."""
    B, S, d = x.shape
    H = n_heads
    hd = wq.shape[1] // H
    m = head_mask_expand(head_mask, wq.shape[1])
    xf = x.astype(jnp.float32).reshape(B * S, d)
    q = (xf @ (wq.astype(jnp.float32) * m)).reshape(B, S, H, hd)
    k = (xf @ (wk.astype(jnp.float32) * m)).reshape(B, S, H, hd)
    v = (xf @ (wv.astype(jnp.float32) * m)).reshape(B, S, H, hd)
    s = jnp.einsum("bqhe,bkhe->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(causal[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqk,bkhe->bqhe", p, v).reshape(B * S, H * hd)
    out = (ctx * m) @ (wo.astype(jnp.float32))
    return out.reshape(B, S, d).astype(x.dtype)


def decode_gqa_ref(q, k, v, lengths):
    """q: (B,H,hd); k,v: (B,C,KV,hd); lengths: (B,) valid prefix lengths.
    Returns (B,H,hd)."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, kf) / jnp.sqrt(hd)
    C = k.shape[1]
    valid = jnp.arange(C)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", w, vf)
    return out.reshape(B, H, hd).astype(q.dtype)


def rwkv_chunk_scan_ref(r, k, v, logw, u):
    """Naive per-token RWKV-6 recurrence. r,k,v,logw: (B,S,H,N); u: (H,N).
    Returns (y (B,S,H,N) fp32, state (B,H,N,N) fp32)."""
    B, S, H, N = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S0, inp):
        rt, kt, vt, wt = inp                              # (B,H,N)
        y = (jnp.einsum("bhn,bhnm->bhm", rt, S0)
             + jnp.einsum("bhn,bhn->bh", rt,
                          uf[None] * kt)[..., None] * vt)
        S1 = wt[..., None] * S0 + kt[..., None] * vt[..., None, :]
        return S1, y
    sw = lambda t: t.transpose(1, 0, 2, 3)
    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    state, ys = jax.lax.scan(step, S0, (sw(rf), sw(kf), sw(vf), sw(w)))
    return ys.transpose(1, 0, 2, 3), state
