"""Pallas TPU kernels: head-masked attention projections (DESIGN.md §10).

Invariant dropout at attention granularity drops whole *heads* — the
head-dim analogue of §2's 128-neuron FFN blocks. A head is the natural
dropout unit because the Q/K/V projection columns and O-projection rows of
one head form a closed consumer set: zeroing all four makes the head's
contribution to the residual stream exactly zero (softmax over the other
heads is untouched — each head's softmax is independent).

Two kernel shapes cover the four projections:

  * `masked_head_proj`  — x @ W with a per-head column mask (Q, K, V).
    Grid (m_blocks, H), one head-slab of W per j step; dropped heads skip
    the matmul and write a zero tile (their output *exists* but is zero —
    downstream shapes stay static, §8's mask-is-data idiom).
  * `masked_head_merge` — a @ W_o with a per-head row mask (O). Grid
    (m_blocks, H) with H innermost and an fp32 accumulator tile, exactly
    the masked-FFN forward structure minus the activation.

Both are wrapped in `jax.custom_vjp` with Pallas backwards that skip
dropped heads through the same scalar-prefetch mask path (dW tiles of
dropped heads are exact zeros by construction). `masked_attention`
composes them into a full MHA block whose FLOPs — projections *and*
score/value einsums — scale with the number of kept heads, while
`kernels/decode_gqa.py` remains the inference-side consumer of the same
head layout (heads contiguous in the feature dim, `hd` fastest).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _validate_proj(x, w, head_mask, merge: bool):
    if x.ndim != 2:
        raise ValueError(f"x must be (M, din), got {x.shape}")
    if w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"w must be ({x.shape[1]}, dout), got {w.shape}")
    H = head_mask.shape[0] if head_mask.ndim == 1 else -1
    if head_mask.ndim != 1 or H < 1:
        raise ValueError(f"head_mask must be (H,) 0/1, got {head_mask.shape}")
    ax = 0 if merge else 1            # the head-partitioned axis of w
    if w.shape[ax] % H != 0:
        raise ValueError(
            f"w axis {ax} ({w.shape[ax]}) must divide evenly into H={H} "
            f"heads — the head-masked kernels tile W per head "
            f"(DESIGN.md §10); pad the projection or fix the mask length")


def _proj_kernel(mask_ref, x_ref, w_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(mask_ref[j] > 0)
    def _keep():
        y_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                             preferred_element_type=jnp.float32
                             ).astype(y_ref.dtype)

    @pl.when(mask_ref[j] == 0)
    def _drop():
        y_ref[...] = jnp.zeros_like(y_ref)


def _proj_dx_kernel(mask_ref, g_ref, w_ref, dx_ref, acc_ref, *, n_h):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[j] > 0)
    def _keep():
        acc_ref[...] += jnp.dot(g_ref[...], w_ref[...].T,
                                preferred_element_type=jnp.float32)

    @pl.when(j == n_h - 1)
    def _fin():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def _proj_dw_kernel(mask_ref, g_ref, x_ref, dw_ref, acc_ref, *, n_m):
    j = pl.program_id(0)          # head (outer)
    i = pl.program_id(1)          # m block (inner: tile revisited)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[j] > 0)
    def _keep():
        acc_ref[...] += jnp.dot(x_ref[...].T, g_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(i == n_m - 1)
    def _fin():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def _merge_kernel(mask_ref, a_ref, w_ref, y_ref, acc_ref, *, n_h):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[j] > 0)
    def _keep():
        acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(j == n_h - 1)
    def _fin():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _merge_da_kernel(mask_ref, g_ref, w_ref, da_ref):
    j = pl.program_id(1)

    @pl.when(mask_ref[j] > 0)
    def _keep():
        da_ref[...] = jnp.dot(g_ref[...], w_ref[...].T,
                              preferred_element_type=jnp.float32
                              ).astype(da_ref.dtype)

    @pl.when(mask_ref[j] == 0)
    def _drop():
        da_ref[...] = jnp.zeros_like(da_ref)


def _pad_rows(arr, block_m):
    pad = (-arr.shape[0]) % block_m
    if pad:
        arr = jnp.pad(arr, ((0, pad), (0, 0)))
    return arr


def _call(kernel, tmask, args, grid, in_specs, out_specs, out_shape,
          scratch, interpret):
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=out_specs, scratch_shapes=scratch),
        out_shape=out_shape, interpret=interpret)(tmask, *args)


@functools.lru_cache(maxsize=None)
def _proj_vjp(block_m, interpret):
    def _impl(x, w, mask):
        M, din = x.shape
        dout = w.shape[1]
        H = mask.shape[0]
        hs = dout // H
        bm = min(block_m, M)
        xp = _pad_rows(x, bm)
        tmask = (mask > 0).astype(jnp.int32)
        grid = (xp.shape[0] // bm, H)
        y = _call(
            _proj_kernel, tmask, [xp, w], grid,
            [pl.BlockSpec((bm, din), lambda i, j, m: (i, 0)),
             pl.BlockSpec((din, hs), lambda i, j, m: (0, j))],
            pl.BlockSpec((bm, hs), lambda i, j, m: (i, j)),
            jax.ShapeDtypeStruct((xp.shape[0], dout), x.dtype),
            [], interpret)
        return y[:M]

    def _dx(gy, x, w, mask):
        M, din = x.shape
        dout = w.shape[1]
        H = mask.shape[0]
        hs = dout // H
        bm = min(block_m, M)
        gp = _pad_rows(gy, bm)
        tmask = (mask > 0).astype(jnp.int32)
        grid = (gp.shape[0] // bm, H)
        dx = _call(
            functools.partial(_proj_dx_kernel, n_h=H), tmask, [gp, w], grid,
            [pl.BlockSpec((bm, hs), lambda i, j, m: (i, j)),
             pl.BlockSpec((din, hs), lambda i, j, m: (0, j))],
            pl.BlockSpec((bm, din), lambda i, j, m: (i, 0)),
            jax.ShapeDtypeStruct((gp.shape[0], din), x.dtype),
            [pltpu.VMEM((bm, din), jnp.float32)], interpret)
        return dx[:M]

    def _dw(gy, x, w, mask):
        M, din = x.shape
        dout = w.shape[1]
        H = mask.shape[0]
        hs = dout // H
        bm = min(block_m, M)
        gp, xp = _pad_rows(gy, bm), _pad_rows(x, bm)
        tmask = (mask > 0).astype(jnp.int32)
        n_m = xp.shape[0] // bm
        return _call(
            functools.partial(_proj_dw_kernel, n_m=n_m), tmask, [gp, xp],
            (H, n_m),
            [pl.BlockSpec((bm, hs), lambda j, i, m: (i, j)),
             pl.BlockSpec((bm, din), lambda j, i, m: (i, 0))],
            pl.BlockSpec((din, hs), lambda j, i, m: (0, j)),
            jax.ShapeDtypeStruct((din, dout), w.dtype),
            [pltpu.VMEM((din, hs), jnp.float32)], interpret)

    @jax.custom_vjp
    def f(x, w, mask):
        return _impl(x, w, mask)

    def fwd(x, w, mask):
        return _impl(x, w, mask), (x, w, mask)

    def bwd(res, gy):
        x, w, mask = res
        return _dx(gy, x, w, mask), _dw(gy, x, w, mask), jnp.zeros_like(mask)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _merge_vjp(block_m, interpret):
    def _impl(a, w, mask):
        M, dout_in = a.shape
        d = w.shape[1]
        H = mask.shape[0]
        hs = dout_in // H
        bm = min(block_m, M)
        ap = _pad_rows(a, bm)
        tmask = (mask > 0).astype(jnp.int32)
        grid = (ap.shape[0] // bm, H)
        y = _call(
            functools.partial(_merge_kernel, n_h=H), tmask, [ap, w], grid,
            [pl.BlockSpec((bm, hs), lambda i, j, m: (i, j)),
             pl.BlockSpec((hs, d), lambda i, j, m: (j, 0))],
            pl.BlockSpec((bm, d), lambda i, j, m: (i, 0)),
            jax.ShapeDtypeStruct((ap.shape[0], d), a.dtype),
            [pltpu.VMEM((bm, d), jnp.float32)], interpret)
        return y[:M]

    def _da(gy, a, w, mask):
        M = a.shape[0]
        d = w.shape[1]
        H = mask.shape[0]
        hs = a.shape[1] // H
        bm = min(block_m, M)
        gp = _pad_rows(gy, bm)
        tmask = (mask > 0).astype(jnp.int32)
        grid = (gp.shape[0] // bm, H)
        da = _call(
            _merge_da_kernel, tmask, [gp, w], grid,
            [pl.BlockSpec((bm, d), lambda i, j, m: (i, 0)),
             pl.BlockSpec((hs, d), lambda i, j, m: (j, 0))],
            pl.BlockSpec((bm, hs), lambda i, j, m: (i, j)),
            jax.ShapeDtypeStruct((gp.shape[0], a.shape[1]), a.dtype),
            [], interpret)
        return da[:M]

    def _dw(gy, a, w, mask):
        M = a.shape[0]
        d = w.shape[1]
        H = mask.shape[0]
        hs = a.shape[1] // H
        bm = min(block_m, M)
        gp, ap = _pad_rows(gy, bm), _pad_rows(a, bm)
        tmask = (mask > 0).astype(jnp.int32)
        n_m = ap.shape[0] // bm
        return _call(
            functools.partial(_proj_dw_kernel, n_m=n_m), tmask, [gp, ap],
            (H, n_m),
            [pl.BlockSpec((bm, d), lambda j, i, m: (i, 0)),
             pl.BlockSpec((bm, hs), lambda j, i, m: (i, j))],
            pl.BlockSpec((hs, d), lambda j, i, m: (j, 0)),
            jax.ShapeDtypeStruct((a.shape[1], d), w.dtype),
            [pltpu.VMEM((hs, d), jnp.float32)], interpret)

    @jax.custom_vjp
    def f(a, w, mask):
        return _impl(a, w, mask)

    def fwd(a, w, mask):
        return _impl(a, w, mask), (a, w, mask)

    def bwd(res, gy):
        a, w, mask = res
        # dW_o = a_masked^T @ gy per head; _proj_dw_kernel's x.T @ g with
        # (x=a-slab, g=gy) is exactly that — dropped-head rows stay zero.
        return _da(gy, a, w, mask), _dw(gy, a, w, mask), jnp.zeros_like(mask)

    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# public entry points

@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def masked_head_proj(x, w, head_mask, *, block_m: int = 128,
                     interpret: bool = True):
    """Head-masked input projection ``y = x @ w`` (Q/K/V side).

    Shapes/dtypes: ``x`` (M, din) float32/bf16; ``w`` (din, H*hd) with
    heads laid out contiguously, head-dim fastest (the
    `kernels/decode_gqa.py` layout); ``head_mask`` (H,) 0/1 (int or
    float). Returns (M, H*hd) in ``x.dtype`` — dropped heads' columns are
    exact zeros, kept by skipping (not multiplying).
    Granularity/padding: the mask is per-HEAD; H must divide w.shape[1]
    (ValueError otherwise). M pads internally to ``block_m``. For compiled
    TPU lowering hd should be a multiple of 128 (lane width); interpret
    mode accepts any hd. Differentiable: custom_vjp with Pallas dx/dW
    kernels; dW head-slabs of dropped heads are exact zeros."""
    _validate_proj(x, w, head_mask, merge=False)
    f = _proj_vjp(block_m, interpret)
    return f(x, w, head_mask.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def masked_head_merge(a, w, head_mask, *, block_m: int = 128,
                      interpret: bool = True):
    """Head-masked output merge ``y = a @ w`` (O-projection side).

    Shapes/dtypes: ``a`` (M, H*hd) per-head attention outputs (decode_gqa
    layout, head-dim fastest); ``w`` (H*hd, d); ``head_mask`` (H,) 0/1.
    Returns (M, d) in ``a.dtype``, accumulating only over kept heads (fp32
    accumulator, H innermost in the grid).
    Granularity/padding: per-head mask; H must divide a.shape[1]
    (ValueError otherwise); M pads internally to ``block_m``; hd should be
    128-aligned for compiled TPU lowering. Differentiable: custom_vjp with
    Pallas da/dW kernels; dW rows of dropped heads are exact zeros."""
    _validate_proj(a, w, head_mask, merge=True)
    f = _merge_vjp(block_m, interpret)
    return f(a, w, head_mask.astype(jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("n_heads", "block_m", "interpret"))
def masked_attention(x, wq, wk, wv, wo, head_mask, *, n_heads: int,
                     block_m: int = 128, interpret: bool = True):
    """Head-masked multi-head self-attention (training-side composition).

    Shapes/dtypes: ``x`` (B, S, d); ``wq``/``wk``/``wv`` (d, H*hd);
    ``wo`` (H*hd, d); ``head_mask`` (H,) 0/1 with ``H == n_heads``.
    Returns (B, S, d) in ``x.dtype``.

    Q/K/V run through `masked_head_proj` (dropped heads project to zero
    without touching the MXU), causal softmax attention runs per head in
    plain jnp — each head's softmax is independent, so dropped heads
    produce v=0 ⇒ per-head output 0 regardless of their (garbage-free,
    all-zero) scores — and `masked_head_merge` accumulates only kept heads
    into the residual. Equivalent to dense attention over
    `head_mask ⊙ params` (column/row head-slabs zeroed), gradient
    included: tested in tests/test_kernel_grad.py. FLOPs scale with kept
    heads in every matmul except the (cheap) softmax normalizers.
    Padding: S pads to ``block_m`` internally; hd should be 128-aligned
    for compiled TPU lowering."""
    if head_mask.shape != (n_heads,):
        raise ValueError(f"head_mask must be (n_heads={n_heads},), "
                         f"got {head_mask.shape}")
    B, S, d = x.shape
    H = n_heads
    hd = wq.shape[1] // H
    x2 = x.reshape(B * S, d)
    q = masked_head_proj(x2, wq, head_mask, block_m=block_m,
                         interpret=interpret).reshape(B, S, H, hd)
    k = masked_head_proj(x2, wk, head_mask, block_m=block_m,
                         interpret=interpret).reshape(B, S, H, hd)
    v = masked_head_proj(x2, wv, head_mask, block_m=block_m,
                         interpret=interpret).reshape(B, S, H, hd)
    scores = jnp.einsum("bqhe,bkhe->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkhe->bqhe", probs, v).reshape(B * S, H * hd)
    out = masked_head_merge(ctx, wo, head_mask, block_m=block_m,
                            interpret=interpret)
    return out.reshape(B, S, d)
