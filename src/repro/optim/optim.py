"""Minimal stacked-tree-aware optimizers: SGD, SGD-momentum, AdamW.

State is a pytree mirroring params; works with (L, ...) stacked arrays and
with sub-model (gathered) trees alike.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable   # (grads, state, params, lr) -> (new_params, new_state)


def _zeros_like_tree(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def sgd():
    def init(params):
        return {}

    def update(grads, state, params, lr):
        new_p = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                             params, grads)
        return new_p, state
    return Optimizer("sgd", init, update)


def sgdm(momentum=0.9):
    """Momentum buffer keeps the *param* dtype (bf16 params at 480B scale
    cannot afford an fp32 buffer)."""
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(m_.dtype),
                         state["m"], grads)
        new_p = jax.tree.map(lambda p, m_: p - lr * m_.astype(p.dtype),
                             params, m)
        return new_p, {"m": m}
    return Optimizer("sgdm", init, update)


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0):
    def init(params):
        return {"m": _zeros_like_tree(params),
                "v": _zeros_like_tree(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return p - (lr * step).astype(p.dtype)
        new_p = jax.tree.map(upd, params, m, v)
        return new_p, {"m": m, "v": v, "t": t}
    return Optimizer("adamw", init, update)


_FACTORIES = {"sgd": sgd, "sgdm": sgdm, "adamw": adamw}


def make_optimizer(name: str) -> Optimizer:
    return _FACTORIES[name]()


def init_opt(name: str, params):
    return make_optimizer(name).init(params)


def opt_update(name: str, grads, state, params, lr):
    return make_optimizer(name).update(grads, state, params, lr)
