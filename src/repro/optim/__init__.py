from repro.optim.optim import (init_opt, opt_update, sgd, sgdm, adamw,
                               make_optimizer)
