"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be started fresh (jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all

Per combo this produces:
  * proof of compilation on the production mesh (16x16; and 2x16x16 with
    --multi-pod), with memory_analysis() bytes-per-device,
  * roofline terms from cost_analysis() + HLO collective parsing, corrected
    for scan trip counts via per-segment probe lowerings (XLA counts a
    while-body once — measured; see EXPERIMENTS.md §Methodology).
Results are written incrementally to experiments/dryrun/<combo>.json.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs  # noqa: E402
from repro.configs.shapes import window_override_for  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import sharding as shlib  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import encdec, transformer  # noqa: E402
from repro.models import model as model_lib  # noqa: E402


# ---------------------------------------------------------------------------
# probe lowerings (per-segment bodies; trip-count roofline correction)

def _strip_stack(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)


def _unit_param_specs(cfg, seg):
    one = jax.eval_shape(
        lambda k: {f"l{i}": transformer._init_layer(k, s, cfg)
                   for i, s in enumerate(seg.unit)},
        jax.random.PRNGKey(0))
    return one


def _probe_seq(cfg, seg, mode, S, B, wo, unroll):
    """Lower one segment body at full shapes. mode train => fwd+bwd."""
    positions_const = jnp.arange(S, dtype=jnp.int32)

    def apply_unit(up, x):
        for i, spec in enumerate(seg.unit):
            x, _, aux = transformer._apply_layer_seq(
                spec, up[f"l{i}"], x, cfg, positions_const, None, wo,
                unroll, False)
        return x

    if mode == "train":
        def fn(up, x, ct):
            y, vjp = jax.vjp(apply_unit, up, x)
            gp, gx = vjp(ct)
            return y, gp, gx
    else:
        def fn(up, x):
            return apply_unit(up, x)
    return fn


def _probe_decode(cfg, seg, B, S, wo, mla_absorb=False):
    def fn(up, uc, x, pos):
        new_u = {}
        for i, spec in enumerate(seg.unit):
            x, nc = transformer._apply_layer_decode(
                spec, up[f"l{i}"], x, uc[f"l{i}"], cfg, pos, None, wo,
                mla_absorb)
            new_u[f"l{i}"] = nc
        return x, new_u
    return fn


def probe_terms(cfg, mesh, shape, mode, wo, compile_probe, variant=None):
    """Returns list of (repeats, RooflineTerms_per_repeat)."""
    variant = variant or {}
    fsdp = not variant.get("no_fsdp", False)
    seq_shard = variant.get("cache_seq_shard", False)
    out = []
    B, S = shape.global_batch, shape.seq_len
    x_spec = jax.ShapeDtypeStruct((B, 1 if mode == "decode" else S,
                                   cfg.d_model), jnp.dtype(cfg.dtype))
    bsym = steps_lib.batch_spec_sym(mesh, B)
    x_shard = NamedSharding(mesh, shlib.pspec(bsym, None, None))

    if cfg.is_encdec:
        segs_info = [("enc", cfg.enc_layers), ("dec", cfg.n_layers)]
        for name, repeats in segs_info:
            terms = _probe_encdec(cfg, mesh, shape, mode, wo, name,
                                  x_spec, x_shard, compile_probe)
            if terms is not None:
                out.append((repeats, terms))
        return out

    segs = transformer.build_segments(cfg)
    for seg in segs:
        up_spec = _unit_param_specs(cfg, seg)
        up_shard = shlib.param_shardings(
            up_spec, mesh, fsdp=fsdp,
            kv_shardable=cfg.n_kv_heads % mesh.shape.get("model", 1) == 0)
        with shlib.mesh_context(mesh):
            if mode in ("train", "prefill"):
                # rolled + unrolled probes: correct inner chunk loops too
                t_un = _compile_terms(
                    _probe_seq(cfg, seg, mode, S, B, wo, unroll=True),
                    (up_spec, x_spec) + ((x_spec,) if mode == "train" else ()),
                    (up_shard, x_shard) + ((x_shard,) if mode == "train" else ()),
                    compile_probe)
                out.append((seg.repeats, t_un))
            else:
                cs = transformer.stack_cache_specs(cfg, B, S, wo)
                idx = segs.index(seg)
                uc_spec = _strip_stack(cs[idx])
                uc_shard = steps_lib.cache_shardings(
                    cfg, mesh,
                    jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                        (1,) + s.shape, s.dtype), uc_spec),
                    seq_shard=seq_shard)
                uc_shard = jax.tree.map(
                    lambda sh: NamedSharding(mesh, P(*sh.spec[1:])), uc_shard)
                pos_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
                pos_shard = NamedSharding(mesh, shlib.pspec(bsym))
                t = _compile_terms(
                    _probe_decode(cfg, seg, B, S, wo,
                                  variant.get("mla_absorb", False)),
                    (up_spec, uc_spec, x_spec, pos_spec),
                    (up_shard, uc_shard, x_shard, pos_shard),
                    compile_probe,
                    decode_cache="seq" if seq_shard else "auto",
                    upos=variant.get("uniform_pos", False))
                out.append((seg.repeats, t))
    return out


def _probe_encdec(cfg, mesh, shape, mode, wo, which, x_spec, x_shard,
                  compile_probe):
    B, S = shape.global_batch, shape.seq_len
    positions_const = jnp.arange(S, dtype=jnp.int32)
    mem_len = model_lib.ENC_MEM_LEN if mode == "decode" else S
    mem_spec = jax.ShapeDtypeStruct((B, mem_len, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    layer_init = (encdec._init_enc_layer if which == "enc"
                  else encdec._init_dec_layer)
    up_spec = jax.eval_shape(lambda k: layer_init(k, cfg),
                             jax.random.PRNGKey(0))
    kv_ok = cfg.n_kv_heads % mesh.shape.get("model", 1) == 0
    up_shard = shlib.param_shardings(up_spec, mesh, kv_shardable=kv_ok)

    with shlib.mesh_context(mesh):
        if which == "enc":
            if mode == "decode":
                return None    # encoder doesn't run at decode
            from repro.models.attention import attn_seq
            from repro.models.layers import apply_ffn, apply_norm

            def apply_unit(p, x):
                h = apply_norm(p["norm1"], x, cfg)
                y, _ = attn_seq(p["attn"], h, cfg, positions_const,
                                causal=False, unroll=True)
                x = x + y
                h2 = apply_norm(p["norm2"], x, cfg)
                return x + apply_ffn(p["ffn"], h2, cfg)
        else:
            if mode == "decode":
                cs = encdec.dec_cache_specs(cfg, B, S, mem_len, wo)
                uc_spec = _strip_stack(cs)
                uc_shard = jax.tree.map(
                    lambda s: NamedSharding(
                        mesh, shlib.guarded_pspec(
                            mesh, s.shape,
                            (steps_lib.batch_spec_sym(mesh, B),)
                            + (None,) * (len(s.shape) - 1))),
                    uc_spec)
                pos_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
                pos_shard = NamedSharding(
                    mesh, shlib.pspec(steps_lib.batch_spec_sym(mesh, B)))
                x1_spec = jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                               jnp.dtype(cfg.dtype))

                def fn(p, c, x, pos):
                    # single decoder layer decode
                    from repro.models.attention import attn_decode, attn_seq
                    from repro.models.layers import apply_ffn, apply_norm
                    h = apply_norm(p["norm1"], x, cfg)
                    y, cc, slots = attn_decode(
                        p["attn"], h, cfg,
                        {k: c["attn"][k] for k in ("k", "v")},
                        c["attn"]["slots"], pos, window=wo)
                    x = x + y
                    hc = apply_norm(p["norm_c"], x, cfg)
                    mpos = jnp.zeros((c["cross_k"].shape[1],), jnp.int32)
                    y, _ = attn_seq(p["cross"], hc, cfg, pos[:, None],
                                    kv_override=(c["cross_k"], c["cross_v"]),
                                    kv_positions=mpos)
                    x = x + y
                    h2 = apply_norm(p["norm2"], x, cfg)
                    x = x + apply_ffn(p["ffn"], h2, cfg)
                    return x, cc
                return _compile_terms(fn, (up_spec, uc_spec, x1_spec, pos_spec),
                                      (up_shard, uc_shard, x_shard, pos_shard),
                                      compile_probe)

            def apply_unit(p, x, mem):
                mem_kv = encdec._cross_kv(p["cross"], mem, cfg)
                x, _ = encdec._dec_layer_seq(p, x, mem_kv, cfg,
                                             positions_const, None, wo,
                                             True, False)
                return x

        if which == "dec" and mode != "decode":
            mem_shard = x_shard
            if mode == "train":
                def fn(p, x, mem, ct):
                    y, vjp = jax.vjp(lambda pp, xx, mm: apply_unit(pp, xx, mm),
                                     p, x, mem)
                    return (y,) + vjp(ct)
                return _compile_terms(fn, (up_spec, x_spec, mem_spec, x_spec),
                                      (up_shard, x_shard, mem_shard, x_shard),
                                      compile_probe)
            return _compile_terms(lambda p, x, mem: apply_unit(p, x, mem),
                                  (up_spec, x_spec, mem_spec),
                                  (up_shard, x_shard, mem_shard),
                                  compile_probe)
        # encoder
        if mode == "train":
            def fn(p, x, ct):
                y, vjp = jax.vjp(apply_unit, p, x)
                return (y,) + vjp(ct)
            return _compile_terms(fn, (up_spec, x_spec, x_spec),
                                  (up_shard, x_shard, x_shard), compile_probe)
        return _compile_terms(apply_unit, (up_spec, x_spec),
                              (up_shard, x_shard), compile_probe)


def _compile_terms(fn, arg_specs, arg_shards, compile_probe=True,
                   decode_cache="auto", upos=False):
    with shlib.decode_cache_context(decode_cache), \
            shlib.uniform_pos_context(upos):
        lowered = jax.jit(fn, in_shardings=arg_shards).lower(*arg_specs)
    compiled = lowered.compile()
    return rl.terms_from_compiled(compiled)


# ---------------------------------------------------------------------------
# full-step lowering

def lower_full(cfg, mesh, shape, wo, variant=None):
    variant = variant or {}
    specs = input_specs(cfg, shape)
    fsdp = not variant.get("no_fsdp", False)
    with shlib.mesh_context(mesh):
        if shape.mode == "train":
            mask_rate = variant.get("fluid_mask")
            fn = steps_lib.make_train_step(cfg,
                                           with_masks=mask_rate is not None)
            in_sh, out_sh, args = steps_lib.shardings_for(
                cfg, mesh, "train", specs, fsdp=fsdp)
            if mask_rate is not None:
                msp, msh = steps_lib.mask_specs_and_shardings(cfg, mesh)
                args = args + (msp,)
                in_sh = in_sh + (msh,)
            jfn = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=(in_sh[0], in_sh[1], None),
                          donate_argnums=(0, 1))
        elif shape.mode == "prefill":
            fn = steps_lib.make_prefill_step(cfg, window_override=wo)
            in_sh, _, args = steps_lib.shardings_for(
                cfg, mesh, "prefill", specs, fsdp=fsdp)
            jfn = jax.jit(fn, in_shardings=in_sh)
        else:
            fn = steps_lib.make_serve_step(
                cfg, window_override=wo,
                mla_absorb=variant.get("mla_absorb", False))
            in_sh, _, args = steps_lib.shardings_for(
                cfg, mesh, "decode", specs, window_override=wo, fsdp=fsdp,
                cache_seq_shard=variant.get("cache_seq_shard", False))
            jfn = jax.jit(fn, in_shardings=in_sh)
        dc = ("seq" if variant.get("cache_seq_shard") else "auto")
        t0 = time.time()
        with shlib.decode_cache_context(dc), \
                shlib.uniform_pos_context(variant.get("uniform_pos", False)):
            lowered = jfn.lower(*args)
        compiled = lowered.compile()
        dt = time.time() - t0
    return lowered, compiled, dt


# §Perf hillclimb variants (EXPERIMENTS.md §Perf): each entry transforms the
# lowering — config overrides, sharding strategy, or step semantics.
VARIANTS = {
    "base": {},
    # serve: drop ZeRO-style param sharding (no per-step weight gathers) and
    # hold serving weights in bf16
    "serve_tp_bf16": {"no_fsdp": True,
                      "cfg_overrides": {"param_dtype": "bfloat16"}},
    # + sequence-sharded KV cache (cross-device flash-decoding)
    "serve_seqcache": {"no_fsdp": True, "cache_seq_shard": True,
                       "cfg_overrides": {"param_dtype": "bfloat16"}},
    # + synchronized-batch single-slot cache write
    "serve_upos": {"no_fsdp": True, "cache_seq_shard": True,
                   "uniform_pos": True,
                   "cfg_overrides": {"param_dtype": "bfloat16"}},
    # MLA absorbed decode (DeepSeek/MiniCPM): attend in latent space
    "mla_absorb": {"mla_absorb": True, "no_fsdp": True, "cache_seq_shard": True,
                   "cfg_overrides": {"param_dtype": "bfloat16"}},
    # RWKV chunk-size sweep: decay-tensor traffic scales with chunk length
    "rwkv_chunk32": {"cfg_overrides": {"rwkv_chunk": 32}},
    "rwkv_chunk16": {"cfg_overrides": {"rwkv_chunk": 16}},
    "rwkv_chunk128": {"cfg_overrides": {"rwkv_chunk": 128}},
    "rwkv_c128_bf16": {"cfg_overrides": {"rwkv_chunk": 128,
                                         "rwkv_chunk_dtype": "bfloat16"}},
    # FLuID straggler sub-models: masked (one compile, any mask) vs the
    # physically extracted r=0.75 sub-model (compute actually shrinks)
    "fluid_mask_r75": {"fluid_mask": 0.75},
    "submodel_r75": {"dff_scale": 0.75},
    "submodel_r50": {"dff_scale": 0.5},
    # microbatching depth
    "accum4": {"cfg_overrides": {"grad_accum": 4}},
}


def run_combo(arch, shape_name, multi_pod, probes=True, variant_name="base"):
    variant = VARIANTS[variant_name]
    cfg = get_config(arch)
    if variant.get("cfg_overrides"):
        cfg = cfg.with_overrides(**variant["cfg_overrides"])
    if variant.get("dff_scale"):
        sc = variant["dff_scale"]
        over = {"d_ff": int(cfg.d_ff * sc) // 128 * 128}
        if cfg.n_experts:
            over["moe_d_ff"] = int(cfg.moe_ff * sc) // 64 * 64
        cfg = cfg.with_overrides(**over)
    shape = INPUT_SHAPES[shape_name]
    wo = window_override_for(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for s in mesh.shape.values():
        n_chips *= s

    lowered, compiled, dt = lower_full(cfg, mesh, shape, wo, variant)
    ma = compiled.memory_analysis()
    base = rl.terms_from_compiled(compiled)

    result = {
        "arch": arch, "shape": shape_name, "variant": variant_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode, "window_override": wo,
        "compile_s": round(dt, 2),
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_estimate_per_device": (ma.argument_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         + ma.output_size_in_bytes
                                         - ma.alias_size_in_bytes),
        },
        "uncorrected": base.to_dict(),
    }

    if probes and not multi_pod:
        per_seg = probe_terms(cfg, mesh, shape, shape.mode, wo,
                              compile_probe=True, variant=variant)
        corrected = base
        for repeats, terms in per_seg:
            # full module contains each body once (rolled); probes are
            # unrolled: corrected = full - rolled_once + repeats*unrolled.
            # We approximate rolled_once by terms/inner_unroll when the probe
            # was unrolled; in practice body-once ≈ terms for decode and the
            # dominant correction is the (repeats-1)x term, so we use:
            corrected = corrected + terms.scaled(max(repeats - 1, 0))
        result["roofline"] = corrected.to_dict()
        result["probe_segments"] = [
            {"repeats": r, **t.to_dict()} for r, t in per_seg]

        n_active = active_params(cfg)
        mf = rl.model_flops(cfg, shape, n_active)
        result["model_flops_global"] = mf
        result["model_flops_per_device"] = mf / n_chips
        hw = corrected.flops
        result["useful_flops_ratio"] = (mf / n_chips) / hw if hw else 0.0
    return result


def active_params(cfg) -> int:
    """Active parameter count (MoE: top-k + shared experts only)."""
    sp = model_lib.param_specs(cfg)
    total = sum(x.size for x in jax.tree.leaves(sp))
    if cfg.n_experts:
        def moe_size(tree):
            n = 0
            for k, v in tree.items():
                if k == "moe":
                    for kk in ("w_in", "w_gate", "w_out"):
                        if kk in v:
                            n += v[kk].size
                elif isinstance(v, dict):
                    n += moe_size(v)
            return n
        routed = moe_size(sp)
        total = total - routed + routed * cfg.top_k // cfg.n_experts
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true",
                    help="also compile the 2x16x16 mesh")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    os.makedirs(args.out, exist_ok=True)

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if args.multi_pod or args.multi_pod_only:
        meshes.append(True)

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                if args.variant != "base":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag}")
                    continue
                t0 = time.time()
                try:
                    res = run_combo(arch, shape_name, mp,
                                    probes=not args.no_probes,
                                    variant_name=args.variant)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=2)
                    rt = res.get("roofline", res["uncorrected"])
                    print(f"[ok]   {tag} compile={res['compile_s']}s "
                          f"bottleneck={rt['bottleneck']} "
                          f"mem/dev={res['memory']['peak_estimate_per_device']/2**30:.2f}GiB "
                          f"wall={time.time()-t0:.0f}s", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    with open(os.path.join(args.out, tag + ".FAIL"), "w") as f:
                        f.write(traceback.format_exc())
                    print(f"[FAIL] {tag}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e[:200])
    else:
        print("\nall combos lowered + compiled OK")


if __name__ == "__main__":
    main()
