"""Training driver.

Two modes:
  * plain distributed training of any assigned arch on synthetic LM data
    (``--arch stablelm-12b --steps 50``), mesh-aware when >1 device;
  * **FLuID pod-level training** (``--fluid``): client shards = data-axis
    groups; one shard is an emulated straggler that trains the masked
    sub-model built from invariant FFN-unit stats (Algorithm 1 transplanted
    to the datacenter — see DESIGN.md §2).

CPU-friendly: with a single device it runs the smoke config unsharded.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import transformer_hooks as hooks
from repro.core.straggler import pick_rate
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import model as model_lib
from repro.optim import make_optimizer


def synth_batch(rng, cfg, batch, seq):
    """Synthetic LM data with learnable bigram structure."""
    v = min(cfg.vocab_size, 512)
    base = rng.randint(0, v, size=(batch, seq), dtype=np.int32)
    tokens = np.cumsum(base, axis=1) % v       # locally predictable drift
    out = {"tokens": jnp.asarray(tokens[:, :-1]),
           "targets": jnp.asarray(tokens[:, 1:])}
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.randn(batch, seq - 1, cfg.d_model).astype(np.float32) * 0.1
        ).astype(cfg.dtype)
    return out


def run_plain(cfg, steps, batch, seq, log_every=10, ckpt=None):
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg),
                      donate_argnums=shlib.donate_args(0, 1))
    rng = np.random.RandomState(0)
    losses = []
    for i in range(steps):
        b = synth_batch(rng, cfg, batch, seq + 1)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss {loss:.4f} "
                  f"({time.perf_counter() - t0:.2f}s)", flush=True)
    if ckpt:
        save_checkpoint(ckpt, {"params": params},
                        meta={"steps": steps, "final_loss": losses[-1]})
    return params, losses


def run_fluid(cfg, steps, batch, seq, rate=None, calibrate_every=5,
              straggler_slowdown=1.3, log_every=5):
    """Pod-level FLuID: one client shard is slow; every calibration step the
    server re-derives its sub-model from invariant unit statistics."""
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    # params can't be donated here: prev_params aliases them across steps
    # for the invariant-unit statistics. opt_state is dead after each call.
    full_step = jax.jit(make_train_step(cfg),
                        donate_argnums=shlib.donate_args(1))
    masked_step = jax.jit(make_train_step(cfg, with_masks=True),
                          donate_argnums=shlib.donate_args(1))
    rng = np.random.RandomState(0)

    r = rate or pick_rate(straggler_slowdown)
    masks = None
    prev_params = params
    log = []
    for i in range(steps):
        b = synth_batch(rng, cfg, batch, seq + 1)
        if masks is None:
            params, opt_state, metrics = full_step(params, opt_state, b)
        else:
            params, opt_state, metrics = masked_step(params, opt_state, b,
                                                     masks)
        if (i + 1) % calibrate_every == 0:
            stats = hooks.ffn_unit_stats(prev_params, params, cfg)
            masks = hooks.build_masks(stats, cfg, r)
            prev_params = params
        loss = float(metrics["loss"])
        t_full = 1.0 * straggler_slowdown          # modeled step time units
        t_fluid = 1.0 * straggler_slowdown * (r if masks is not None else 1)
        log.append((loss, t_full, t_fluid))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss {loss:.4f} sub-model r={r} "
                  f"{'masked' if masks is not None else 'full'}", flush=True)
    return params, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--fluid", action="store_true")
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke().with_overrides(grad_accum=1)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(min(n_dev, 4), 1) if n_dev > 1 else None

    ctx = shlib.mesh_context(mesh) if mesh else shlib.mesh_context(None)
    with ctx:
        if args.fluid:
            run_fluid(cfg, args.steps, args.batch, args.seq, rate=args.rate)
        else:
            run_plain(cfg, args.steps, args.batch, args.seq, ckpt=args.ckpt)


if __name__ == "__main__":
    main()
