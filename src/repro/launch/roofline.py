"""Three-term roofline model from compiled dry-run artifacts.

  compute    = HLO_FLOPs(per device)          / peak_FLOPs_per_chip
  memory     = HLO_bytes_accessed(per device) / HBM_bandwidth
  collective = wire_bytes(per device)         / ICI_link_bandwidth

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (measured to be
per-device on SPMD modules). Collective wire bytes are parsed from the
compiled HLO text: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op contributes ring-algorithm wire bytes
computed from its (local, post-partition) result shape and replica-group
size. XLA counts a while-loop body once, so the dry-run corrects totals with
per-segment probe lowerings x trip counts (see dryrun.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# TPU v5e constants (per chip) — from the assignment brief.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g          # x result bytes (already gathered size)
    if op == "reduce-scatter":
        return float(g - 1)         # x result bytes (shard) = (g-1)/g x input
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective op type."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str) * _wire_factor(op, _group_size(line))
        out[op] = out.get(op, 0.0) + b
    return out


@dataclass
class RooflineTerms:
    flops: float = 0.0              # per device
    bytes_accessed: float = 0.0     # per device
    wire_bytes: float = 0.0         # per device
    coll_by_type: Dict[str, float] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def step_time(self) -> float:
        """No-overlap upper bound estimate."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def scaled(self, k: float) -> "RooflineTerms":
        return RooflineTerms(self.flops * k, self.bytes_accessed * k,
                             self.wire_bytes * k,
                             {o: b * k for o, b in self.coll_by_type.items()})

    def __add__(self, other: "RooflineTerms") -> "RooflineTerms":
        cbt = dict(self.coll_by_type)
        for o, b in other.coll_by_type.items():
            cbt[o] = cbt.get(o, 0.0) + b
        return RooflineTerms(self.flops + other.flops,
                             self.bytes_accessed + other.bytes_accessed,
                             self.wire_bytes + other.wire_bytes, cbt)

    def to_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes_accessed,
                "wire_bytes": self.wire_bytes,
                "t_compute": self.t_compute, "t_memory": self.t_memory,
                "t_collective": self.t_collective,
                "bottleneck": self.bottleneck,
                "coll_by_type": self.coll_by_type}


def terms_from_compiled(compiled) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    colls = collective_bytes(compiled.as_text())
    return RooflineTerms(flops, byts, sum(colls.values()), colls)


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6·N·D (training) / 2·N·D (inference) useful-FLOPs reference, global."""
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                   else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n_params_active * tokens
