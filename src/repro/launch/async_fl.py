"""Async buffered FL launcher: ``python -m repro.launch.async_fl``.

Config plumbing from flags to the population driver (DESIGN.md §13):
builds a `PopulationConfig` + `AsyncConfig` + `ArrivalModel`, runs the
asynchronous buffered backend against a device-resident `ClientStore`,
and prints per-buffer progress (virtual clock, staleness, dropouts).
``--backend fleet`` runs the synchronous barrier with the *same*
population and latency distribution, so the two invocations form the
BENCH_async.json comparison by hand.

CPU-friendly smoke:

    PYTHONPATH=src python -m repro.launch.async_fl \
        --clients 2000 --cohort 16 --buffer-k 8 --concurrency 32 \
        --rounds 10 --tail-sigma 0.6 --drop-prob 0.05
"""
from __future__ import annotations

import argparse
import sys

from repro.core.straggler import ArrivalModel
from repro.fl.async_rounds import AsyncConfig
from repro.fl.population import PopulationConfig, build_population


def build_cfg(args) -> PopulationConfig:
    async_cfg = None
    if args.backend == "async":
        async_cfg = AsyncConfig(
            buffer_k=args.buffer_k,
            concurrency=args.concurrency,
            staleness_exponent=args.staleness_exponent,
            arrival=ArrivalModel(drop_prob=args.drop_prob,
                                 reconnect_mean=args.reconnect_mean,
                                 seed=args.seed),
            flash_crowds=tuple(
                (int(s), int(n)) for s, n in
                (p.split(":") for p in args.flash_crowd)),
        )
    return PopulationConfig(
        n_clients=args.clients, cohort_size=args.cohort,
        workload=args.workload, backend=args.backend,
        policy=args.policy, straggler_frac_pop=args.straggler_frac,
        tail_sigma=args.tail_sigma, n_partitions=args.partitions,
        samples_per_partition=args.samples, async_cfg=async_cfg,
        seed=args.seed)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.async_fl",
        description="Run FLuID rounds with the async buffered backend "
                    "(or the synchronous fleet barrier for comparison).")
    ap.add_argument("--backend", choices=("async", "fleet"),
                    default="async")
    ap.add_argument("--clients", type=int, default=20_000)
    ap.add_argument("--cohort", type=int, default=32,
                    help="sync cohort size (fleet backend only)")
    ap.add_argument("--rounds", type=int, default=20,
                    help="barrier rounds (fleet) / drained buffers (async)")
    ap.add_argument("--workload", default="synth")
    ap.add_argument("--policy", default="invariant")
    ap.add_argument("--partitions", type=int, default=64)
    ap.add_argument("--samples", type=int, default=100,
                    help="samples per data partition")
    ap.add_argument("--straggler-frac", type=float, default=0.1)
    ap.add_argument("--tail-sigma", type=float, default=0.6,
                    help="client lognormal latency tail (both backends)")
    ap.add_argument("--seed", type=int, default=0)
    # async-only knobs
    ap.add_argument("--buffer-k", type=int, default=16)
    ap.add_argument("--concurrency", type=int, default=128)
    ap.add_argument("--staleness-exponent", type=float, default=0.5)
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="per-dispatch mid-round dropout probability")
    ap.add_argument("--reconnect-mean", type=float, default=30.0)
    ap.add_argument("--flash-crowd", action="append", default=[],
                    metavar="STEP:EXTRA",
                    help="dispatch EXTRA clients beyond the concurrency "
                         "target at server step STEP (repeatable)")
    ap.add_argument("--eval-every", type=int, default=5)
    args = ap.parse_args(argv)

    sim = build_population(build_cfg(args))
    for step in range(args.rounds):
        ev = args.eval_every and (step + 1) % args.eval_every == 0
        log = sim.run_round(eval_now=bool(ev))
        clock = getattr(sim, "clock", None)
        line = (f"step {step:3d}  time {log.round_time:7.2f}s"
                if clock is None else
                f"buffer {step:3d}  clock {clock:8.2f}s"
                f"  stale max {log.staleness_max:3.0f}")
        line += f"  stragglers {len(log.stragglers):3d}"
        if ev:
            line += f"  acc {log.accuracy:.4f}"
        print(line)
    if args.backend == "async":
        print(f"done: {args.rounds} buffers x K={args.buffer_k}, "
              f"virtual clock {sim.clock:.2f}s, "
              f"dropouts survived {sim.backend.total_drops}, "
              f"in flight {len(sim.backend.in_flight_ids)}")
    else:
        tot = sum(h.round_time for h in sim.server.history)
        print(f"done: {args.rounds} barrier rounds, "
              f"simulated wall-clock {tot:.2f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
