"""Mesh context + sharding rules.

A tiny explicit context (no jax internals) carries the active mesh. Model code
calls ``shard(x, "B", None, "M")`` with symbolic axes:

  "B" -> the batch axes ("pod","data") or ("data",)
  "M" -> the model/tensor axis
  None -> replicated dim

Outside a mesh context (CPU smoke tests) ``shard`` is the identity, so the
same model code runs unsharded.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def decode_cache_mode() -> str:
    """'auto' (let GSPMD propagate) or 'seq' (pin the KV cache sequence axis
    to the model axis inside decode attention — cross-device flash-decode)."""
    return getattr(_STATE, "decode_cache", "auto")


def uniform_pos() -> bool:
    """True => all sequences decode at the same position (synchronized
    batch): the cache update is a single-slot dynamic-update-slice instead
    of a one-hot full-cache rewrite."""
    return getattr(_STATE, "uniform_pos", False)


@contextlib.contextmanager
def uniform_pos_context(on: bool):
    prev = uniform_pos()
    _STATE.uniform_pos = on
    try:
        yield
    finally:
        _STATE.uniform_pos = prev


@contextlib.contextmanager
def decode_cache_context(mode: str):
    prev = decode_cache_mode()
    _STATE.decode_cache = mode
    try:
        yield
    finally:
        _STATE.decode_cache = prev


def serve_kernel_flags() -> dict:
    """Which Pallas serving kernels the decode step should trace in:
    {'ffn': bool, 'attn': bool, 'interpret': bool}. Defaults to all-off —
    the pure-jnp path — because the kernels only pay off on real TPUs
    (interpret mode exists for CPU correctness tests, not speed)."""
    return getattr(_STATE, "serve_kernels",
                   {"ffn": False, "attn": False, "interpret": True})


@contextlib.contextmanager
def serve_kernels_context(ffn: bool = False, attn: bool = False,
                          interpret: bool = True):
    """Opt the serving decode step into the Pallas kernels
    (kernels/masked_ffn.py masked_ffn_batch, kernels/decode_gqa.py).
    Same thread-local idiom as decode_cache_context/uniform_pos_context:
    model code reads the flags at trace time, so the choice is baked into
    whichever program is being compiled under this context."""
    prev = serve_kernel_flags()
    _STATE.serve_kernels = {"ffn": ffn, "attn": attn, "interpret": interpret}
    try:
        yield
    finally:
        _STATE.serve_kernels = prev


def train_kernel_flags() -> dict:
    """Which Pallas kernels the TRAIN step should trace in:
    {'ffn': bool, 'interpret': bool}. Defaults to off (pure-jnp dense
    masking). Unlike serve_kernel_flags this routes the *differentiable*
    custom_vjp kernels (DESIGN.md §10) — both forward and backward skip
    dropped 128-blocks — so it only applies where a per-layer neuron mask
    is being trained through (launch/steps.py make_train_step
    with_masks=True, use_kernels=True)."""
    return getattr(_STATE, "train_kernels",
                   {"ffn": False, "interpret": True})


@contextlib.contextmanager
def train_kernels_context(ffn: bool = False, interpret: bool = True):
    """Opt the train step into the differentiable masked-FFN kernel
    (kernels/masked_ffn.py, custom_vjp). Same trace-time thread-local idiom
    as serve_kernels_context."""
    prev = train_kernel_flags()
    _STATE.train_kernels = {"ffn": ffn, "interpret": interpret}
    try:
        yield
    finally:
        _STATE.train_kernels = prev


def donate_args(*indices: int):
    """Buffer-donation indices for jit'd step functions, gated on backend.

    CPU (and interpret-mode) executables don't support donation — XLA just
    warns and copies — so return () there and the real indices elsewhere.
    Call sites stay declarative: ``donate_argnums=donate_args(0, 1)`` names
    exactly which args are dead after the call (an empty call,
    ``donate_args()``, documents that nothing is donatable).
    """
    if jax.default_backend() == "cpu":
        return ()
    return indices


def batch_axes(mesh: Mesh):
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _resolve(mesh: Mesh, sym):
    if sym is None:
        return None
    if sym == "B":
        ax = batch_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    if sym == "M":
        return "model" if "model" in mesh.axis_names else None
    return sym


def pspec(*syms) -> P:
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*[_resolve(mesh, s) for s in syms])


def _axes_size(mesh, ax) -> int:
    axs = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axs:
        n *= mesh.shape[a]
    return n


def guarded_pspec(mesh, shape, syms, strict: bool = False) -> P:
    """pspec with too-small dims demoted to replicated. With strict=False
    (internal with_sharding_constraint) uneven-but-larger dims stay sharded
    (GSPMD pads internally, e.g. 56 heads over 16); strict=True (jit
    in_shardings, where XLA requires divisibility) demotes uneven dims."""
    out = []
    for dim, sym in zip(shape, syms):
        ax = _resolve(mesh, sym)
        if ax is None:
            out.append(None)
            continue
        n = _axes_size(mesh, ax)
        ok = (dim % n == 0 and dim >= n) if strict else dim >= n
        out.append(ax if ok else None)
    return P(*out)


def shard(x, *syms):
    """with_sharding_constraint under the active mesh; identity without one.
    Dims that don't divide their mesh axes are left replicated."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, guarded_pspec(mesh, x.shape, syms)))


def named(spec_syms) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, pspec(*spec_syms))


# ---------------------------------------------------------------------------
# Parameter sharding rules.
#
# Params are nested dicts; keys are joined with "/" and matched against the
# regex table below (first match wins). Leading segment dims (layer stacks)
# are replicated; the rule names the *trailing* dims of the logical weight.

_RULES = [
    # embeddings / lm head: shard vocab
    (r"(^|/)embed$",               ("M", None)),
    (r"(^|/)lm_head$",             (None, "M")),
    # attention projections: shard heads (q) / replicate small kv
    (r"attn/wq$",                  (None, "M", None)),
    (r"attn/wk$",                  (None, "kv", None)),
    (r"attn/wv$",                  (None, "kv", None)),
    (r"attn/wo$",                  ("M", None, None)),
    (r"attn/(bq)$",                ("M", None)),
    (r"attn/(bk|bv)$",             ("kv", None)),
    (r"attn/bo$",                  (None,)),
    # MLA
    (r"mla/w_dq$",                 (None, None)),
    (r"mla/w_uq$",                 (None, "M", None)),
    (r"mla/wq$",                   (None, "M", None)),
    (r"mla/w_dkv$",                (None, None)),
    (r"mla/w_uk$",                 (None, "M", None)),
    (r"mla/w_uv$",                 (None, "M", None)),
    (r"mla/wo$",                   ("M", None, None)),
    # dense FFN: shard hidden
    (r"ffn/w_in$",                 (None, "M")),
    (r"ffn/w_gate$",               (None, "M")),
    (r"ffn/w_out$",                ("M", None)),
    (r"ffn/b_in$",                 ("M",)),
    (r"ffn/b_gate$",               ("M",)),
    (r"ffn/b_out$",                (None,)),
    # MoE: tensor-parallel experts (expert dim replicated, hidden sharded)
    (r"moe/router$",               (None, None)),
    (r"moe/w_in$",                 (None, None, "M")),
    (r"moe/w_gate$",               (None, None, "M")),
    (r"moe/w_out$",                (None, "M", None)),
    # RWKV-6
    (r"rwkv/(w_r|w_k|w_v|w_g)$",   (None, "M")),
    (r"rwkv/w_o$",                 ("M", None)),
    (r"rwkv/(w_decay|w_u)$",       ("M",)),
    (r"rwkv/lora_.*_a$",           (None, None)),
    (r"rwkv/lora_.*_b$",           (None, "M")),
    (r"rwkv/lora_w_b$",            (None, "M")),
    (r"rwkv/mix_.*$",              (None,)),
    (r"rwkv/ln_.*$",               ("M",)),
    (r"cmix/w_in$",                (None, "M")),
    (r"cmix/w_out$",               ("M", None)),
    (r"cmix/mix_.*$",              (None,)),
    # RG-LRU
    (r"rglru/w_x$",                (None, "M")),
    (r"rglru/w_gate$",             (None, "M")),
    (r"rglru/w_out$",              ("M", None)),
    (r"rglru/conv_.*$",            (None, "M")),
    (r"rglru/(a_param|w_a|w_i|b_a|b_i)$", ("M",) ),
    # norms / scalars: replicate
    (r".*",                        None),
]


def _spec_for(path: str, shape, kv_shardable: bool) -> P:
    ndim = len(shape)
    for pat, tail in _RULES:
        if re.search(pat, path):
            if tail is None:
                return P()
            tail = tuple("M" if (t == "kv" and kv_shardable) else
                         (None if t == "kv" else t) for t in tail)
            lead = (None,) * (ndim - len(tail))
            mesh = current_mesh()
            return guarded_pspec(mesh, shape, lead + tail, strict=True)
    return P()


def param_pspecs(params, kv_shardable: bool = True, fsdp: bool = True):
    """PartitionSpec pytree matching a parameter pytree.

    With fsdp=True, the first still-replicated (and divisible) dim of every
    >=2-D weight is additionally sharded over "data" (ZeRO-3 style); the
    leading layer-stack dim of scanned parameters is skipped so per-layer
    slicing stays local.
    """
    mesh = current_mesh()

    def improve(path, shape, spec):
        if mesh is None or "data" not in mesh.axis_names or len(shape) < 2:
            return spec
        fsdp_axes = batch_axes(mesh)         # ("pod","data") or ("data",)
        d = 1
        for a in fsdp_axes:
            d *= mesh.shape[a]
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = [e for ent in entries if ent
                for e in (ent if isinstance(ent, tuple) else (ent,))]
        if any(a in used for a in fsdp_axes):
            return spec
        start = 1 if ("stack" in path or "seg" in path) else 0
        ax = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
        for i in range(start, len(shape)):
            if entries[i] is None and shape[i] % d == 0 and shape[i] >= d:
                entries[i] = ax
                break
        return P(*entries)

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        spec = _spec_for(prefix, tree.shape, kv_shardable)
        if fsdp:
            spec = improve(prefix, tree.shape, spec)
        return spec
    return walk(params, "")


def param_shardings(params, mesh: Mesh, kv_shardable: bool = True,
                    fsdp: bool = True):
    with mesh_context(mesh):
        specs = param_pspecs(params, kv_shardable, fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
