"""Serving driver: prefill a batch of prompts, then batched greedy decode.

Exercises the same prefill/serve steps the dry-run lowers. On CPU runs the
smoke config; on a real mesh the steps inherit the launch shardings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as shlib
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import model as model_lib


def serve(cfg, batch=2, prompt_len=16, gen_len=16, mla_absorb=False,
          seed=0, greedy=True):
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(cfg, key)
    rng = np.random.RandomState(seed)
    toks = jnp.asarray(rng.randint(0, min(cfg.vocab_size, 256),
                                   (batch, prompt_len), dtype=np.int32))
    batch_in = {"tokens": toks}
    if cfg.is_encdec:
        batch_in["frames"] = jnp.asarray(
            rng.randn(batch, prompt_len, cfg.d_model).astype(np.float32)
            * 0.1).astype(cfg.dtype)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=prompt_len + gen_len))
    step = jax.jit(make_serve_step(cfg, mla_absorb=mla_absorb))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch_in)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(gen_len):
        pos = jnp.full((batch,), prompt_len + t, jnp.int32)
        logits, caches = step(params, caches, tok, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    t_decode = time.perf_counter() - t0
    gen = np.stack(out, 1)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": batch * gen_len / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    with shlib.mesh_context(None):
        gen, stats = serve(cfg, args.batch, args.prompt_len, args.gen_len,
                           mla_absorb=args.mla_absorb)
    print("generated tokens:\n", gen)
    print({k: round(v, 3) for k, v in stats.items()})


if __name__ == "__main__":
    main()
