"""Serving driver: continuous-batching engine over personalized sub-models.

Default path: launch/serving.ServeEngine — one compiled decode chunk serves
a queue of requests with mixed dropout rates, prompt lengths, and generation
lengths (see that module's docstring). ``--baseline`` instead runs the
original synchronous path (one Python-loop token at a time, whole batch in
lockstep) — kept as the reference the engine is benchmarked against in
benchmarks/serve_bench.py. On CPU runs the smoke config; on a real mesh the
steps inherit the launch shardings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as shlib
from repro.launch.serving import ServeEngine, ServeRequest, rate_masks
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import model as model_lib


def serve(cfg, batch=2, prompt_len=16, gen_len=16, mla_absorb=False,
          seed=0, greedy=True):
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(cfg, key)
    rng = np.random.RandomState(seed)
    toks = jnp.asarray(rng.randint(0, min(cfg.vocab_size, 256),
                                   (batch, prompt_len), dtype=np.int32))
    batch_in = {"tokens": toks}
    if cfg.is_encdec:
        batch_in["frames"] = jnp.asarray(
            rng.randn(batch, prompt_len, cfg.d_model).astype(np.float32)
            * 0.1).astype(cfg.dtype)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=prompt_len + gen_len),
                      donate_argnums=shlib.donate_args())
    step = jax.jit(make_serve_step(cfg, mla_absorb=mla_absorb),
                   donate_argnums=shlib.donate_args(1))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch_in)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(gen_len):
        pos = jnp.full((batch,), prompt_len + t, jnp.int32)
        logits, caches = step(params, caches, tok, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    t_decode = time.perf_counter() - t0
    gen = np.stack(out, 1)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": batch * gen_len / max(t_decode, 1e-9)}


def serve_engine(cfg, batch=4, prompt_len=16, gen_len=16, n_requests=None,
                 rates=(1.0, 0.5), mla_absorb=False, seed=0, kernels=None):
    """Queue n_requests with cycling dropout rates and ragged prompt/gen
    lengths through one ServeEngine; returns (results, summary)."""
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params, batch_size=batch,
                      max_prompt_len=prompt_len, max_gen_len=gen_len,
                      mla_absorb=mla_absorb, kernels=kernels)
    rng = np.random.RandomState(seed)
    mask_of = {r: (None if r >= 1.0 else rate_masks(cfg, r, seed=seed))
               for r in rates}
    n_requests = n_requests or 2 * batch
    for i in range(n_requests):
        L = prompt_len if eng.recurrent else int(
            rng.randint(max(1, prompt_len // 2), prompt_len + 1))
        toks = rng.randint(0, min(cfg.vocab_size, 256), (L,), dtype=np.int32)
        g = int(rng.randint(max(1, gen_len // 2), gen_len + 1))
        eng.submit(ServeRequest(toks, gen_len=g, masks=mask_of[
            rates[i % len(rates)]]))
    results = eng.run()
    return results, eng.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--rates", default="1.0,0.5",
                    help="comma-separated sub-model sizes cycled across "
                    "requests (1.0 = full model)")
    ap.add_argument("--baseline", action="store_true",
                    help="synchronous Python-loop decode (no engine)")
    ap.add_argument("--kernels", action="store_true",
                    help="trace the Pallas serving kernels (interpret mode "
                    "off-TPU)")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    with shlib.mesh_context(None):
        if args.baseline:
            gen, stats = serve(cfg, args.batch, args.prompt_len,
                               args.gen_len, mla_absorb=args.mla_absorb)
            print("generated tokens:\n", gen)
            print({k: round(v, 3) for k, v in stats.items()})
            return
        rates = tuple(float(r) for r in args.rates.split(","))
        kern = ({"ffn": True, "attn": True, "interpret": True}
                if args.kernels else None)
        results, summary = serve_engine(
            cfg, args.batch, args.prompt_len, args.gen_len,
            n_requests=args.n_requests, rates=rates,
            mla_absorb=args.mla_absorb, kernels=kern)
        for rid in sorted(results):
            print(f"request {rid}: {results[rid].tolist()}")
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in summary.items()})


if __name__ == "__main__":
    main()
