"""Personalized sub-model serving: one compiled decode program, any client.

FLuID trains per-client sub-models; serving them naively would compile one
decode program per dropout rate (each rate is a different physical shape).
This engine lifts the fleet's "mask is data, not shape" idiom (DESIGN.md §2,
fl/fleet.py) to inference:

  * Every request carries a 0/1 keep-mask over FFN hidden units. Masks are
    deduplicated into a fixed-capacity ``core.maskbank.MaskBank`` — row 0 is
    the all-ones full model — and each batch slot holds an int32 row index.
    The bank's stacked shape is a compile-time constant (capacity rows, tail
    padded with ones), so admitting a never-seen mask cannot recompile.
  * Decode is a single jitted program over the whole slot batch: a
    ``lax.scan`` of ``chunk`` greedy decode steps per dispatch, per-slot
    positions, per-slot masks gathered from the bank. Mixing dropout rates
    0.0 / 0.5 / anything in one batch traces exactly once.
  * Continuous batching at chunk granularity: between chunks the host
    retires finished slots, admits queued requests (prefill + cache splice),
    and re-enters the same compiled chunk. Requests with different prompt
    and generation lengths share the program; empty slots decode garbage
    harmlessly (their cache slots are invalid, softmax over an all-masked
    row is a uniform average of zero values).
  * Prefill is batch-1, right-padded to a fixed prompt capacity, next-token
    logits gathered at the true last position. Right padding is exact for
    attention archs: the padded positions' K/V are causally masked until
    decode overwrites each slot exactly when generation reaches its
    position. Recurrent mixers (rwkv / rg-lru) fold garbage into state, so
    for those archs prompts must fill the prompt window exactly.

Masking the FFN hidden activation equals serving the extracted sub-model:
for act(0) = 0 activations, zeroing h[i] is identical to deleting column i
of w_in/w_gate and row i of w_out (see ``apply_masks_to_params``, the
reference used by tests/test_serving.py for token-level parity).

The Pallas kernels (kernels/masked_ffn.py::masked_ffn_batch tile-skipping
FFN, kernels/decode_gqa.py flash-decode) plug in via
``sharding.serve_kernels_context`` — opt-in, default off on CPU.
"""
from __future__ import annotations

import time

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import transformer_hooks as hooks
from repro.core.dropout import keep_count
from repro.core.maskbank import FULL_MODEL, MaskBank
from repro.launch import sharding as shlib
from repro.models import model as model_lib
from repro.models import transformer


# ---------------------------------------------------------------------------
# mask construction helpers

def rate_masks(cfg: ModelConfig, r: float, policy: str = "ordered",
               seed: int = 0):
    """Per-segment FFN keep-mask pytree for sub-model size r (1.0 = full).

    policy 'ordered' keeps the leading k units per layer (FjORD-style);
    'random' draws k units per (layer, repeat) from ``seed``. Real FLuID
    deployments derive masks from invariant statistics instead
    (core/transformer_hooks.build_masks); this helper exists so serving can
    be exercised without a training run."""
    base = hooks.full_masks(cfg)
    if r >= 1.0:
        return base
    rng = np.random.RandomState(seed)
    out = []
    for seg in base:
        unit = {}
        for lname, entry in seg.items():
            m = {}
            for key, ones in entry.items():
                shape = ones.shape
                f = shape[-1]
                k = keep_count(f, r)
                mask = np.zeros(shape, np.float32)
                if policy == "random":
                    flat = mask.reshape(-1, f)
                    for row in range(flat.shape[0]):
                        flat[row, rng.choice(f, size=k, replace=False)] = 1.0
                else:
                    mask[..., :k] = 1.0
                m[key] = jnp.asarray(mask)
            unit[lname] = m
        out.append(unit)
    return out


def masks_from_keep_map(cfg: ModelConfig, keep_map: Dict[str, np.ndarray]):
    """FL bridge: a core-side keep_map {'seg<si>/l<i>/ffn': kept indices}
    (or the flat {'l<i>': ...} shape of single-segment models) -> the
    serving mask pytree."""
    base = hooks.full_masks(cfg)
    out = []
    for si, seg in enumerate(base):
        unit = {}
        for lname, entry in seg.items():
            m = {}
            for key, ones in entry.items():
                kept = keep_map.get(f"seg{si}/{lname}/{key}",
                                    keep_map.get(lname))
                if kept is None:
                    m[key] = ones
                else:
                    mask = np.zeros(ones.shape, np.float32)
                    mask[..., np.asarray(kept, np.int64)] = 1.0
                    m[key] = jnp.asarray(mask)
            unit[lname] = m
        out.append(unit)
    return out


def mask_fingerprint(masks) -> object:
    if masks is None:
        return FULL_MODEL
    return tuple(np.asarray(leaf).tobytes()
                 for leaf in jax.tree.leaves(masks))


def apply_masks_to_params(params, masks, cfg: ModelConfig):
    """Reference sub-model: bake the FFN masks into the weights (zero the
    dropped units' in-columns, biases, and out-rows). Since act(0) = 0 for
    every supported activation, ``forward(masked_params)`` equals the
    engine's activation-masked decode token for token — the parity oracle
    for tests, not a serving path."""
    segs = transformer.build_segments(cfg)
    new = jax.tree.map(lambda x: x, params)     # shallow-copy the tree
    for si, seg in enumerate(segs):
        seg_p = dict(new["stack"][f"seg{si}"])
        for i, (mixer, ffn) in enumerate(seg.unit):
            entry = masks[si].get(f"l{i}", {})
            if "ffn" not in entry or ffn not in ("dense", "cmix"):
                continue
            m = entry["ffn"]                     # (R, f)
            key = "ffn" if ffn == "dense" else "cmix"
            lp = dict(seg_p[f"l{i}"])
            fp = dict(lp[key])
            for w in ("w_in", "w_gate"):
                if w in fp:
                    fp[w] = fp[w] * m[:, None, :].astype(fp[w].dtype)
            for b in ("b_in", "b_gate"):
                if b in fp:
                    fp[b] = fp[b] * m.astype(fp[b].dtype)
            fp["w_out"] = fp["w_out"] * m[:, :, None].astype(
                fp["w_out"].dtype)
            lp[key] = fp
            seg_p[f"l{i}"] = lp
        new["stack"][f"seg{si}"] = seg_p
    return new


# ---------------------------------------------------------------------------
# requests

@dataclass
class ServeRequest:
    """One generation request: prompt tokens + its personal sub-model.

    masks=None serves the full model (mask-bank row 0). Requests with equal
    masks share a bank row — the dedupe that makes per-client personalization
    affordable at fleet scale."""
    tokens: np.ndarray                 # (L,) int32 prompt
    gen_len: int = 16
    masks: Optional[object] = None     # rate_masks()-shaped pytree or None
    rid: int = field(default=-1)       # assigned by ServeEngine.submit

    def fingerprint(self):
        return mask_fingerprint(self.masks)


# ---------------------------------------------------------------------------
# engine

class ServeEngine:
    """Continuous-batching greedy decoder over personalized sub-models.

    One engine = one compiled prefill step + one compiled cache-splice + one
    compiled decode chunk, shared by every request regardless of its dropout
    rate, prompt length, or generation length. ``trace_counts`` records how
    many times each jitted body actually traced — the no-recompile contract
    is asserted in tests/test_serving.py, not just documented."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_prompt_len: int = 16, max_gen_len: int = 16,
                 chunk: int = 8, bank_size: int = 8, mla_absorb: bool = False,
                 kernels: Optional[dict] = None):
        if cfg.is_encdec:
            raise NotImplementedError(
                "ServeEngine covers decoder-only stacks; encoder-decoder "
                "serving still goes through launch.serve.serve()")
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_prompt_len = max_prompt_len
        self.max_gen_len = max_gen_len
        self.chunk = min(chunk, max_gen_len) if max_gen_len > 1 else 1
        self.mla_absorb = mla_absorb
        self._kernels = kernels or {}
        segs = transformer.build_segments(cfg)
        self.recurrent = any(mixer in ("rglru", "rwkv")
                             for seg in segs for mixer, _ in seg.unit)
        # cache headroom: decode runs in whole chunks, so a slot can write
        # up to chunk-ceil(gen_len-1) positions past its prompt; sizing for
        # the worst case keeps slot idx == pos (no ring wrap), which the
        # decode_gqa kernel's contiguous-prefix lengths rely on.
        n_chunks = -(-(max_gen_len - 1) // self.chunk) if max_gen_len > 1 else 0
        self.cache_len = max_prompt_len + max(n_chunks, 1) * self.chunk
        self.bank = MaskBank(hooks.full_masks(cfg), capacity=bank_size)

        self.trace_counts = {"prefill": 0, "decode": 0, "insert": 0}
        self._build_fns()

        self.caches = self._init_caches()
        self.tok = np.zeros((self.B, 1), np.int32)
        self.pos = np.zeros((self.B,), np.int32)
        self.row = np.zeros((self.B,), np.int32)
        self.queue: deque = deque()
        self.live: Dict[int, dict] = {}
        self._next_rid = 0
        self.stats = {"prefills": 0, "chunks": 0, "decode_tokens": 0,
                      "decode_s": 0.0, "prefill_s": 0.0}

    # ------------------------------------------------------------- compiled
    def _build_fns(self):
        cfg, C, counts = self.cfg, self.cache_len, self.trace_counts
        mla_absorb = self.mla_absorb

        def prefill(params, tokens, length, bank, row):
            counts["prefill"] += 1          # runs on trace only
            masks = jax.tree.map(lambda b: b[row][:, None, None], bank)
            logits, caches, _ = model_lib.forward_seq(
                params, cfg, {"tokens": tokens}, masks=masks,
                want_cache=True, cache_len=C)
            nxt = jnp.take_along_axis(
                logits, (length - 1)[:, None, None], axis=1)[:, 0]
            return jnp.argmax(nxt, -1).astype(jnp.int32), caches

        def insert(caches, new, slot):
            counts["insert"] += 1
            return jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, axis=1), caches, new)

        def decode(params, caches, tok, pos, bank, idx):
            counts["decode"] += 1
            # bank leaf (K, R, f) -> per-slot (R, B, 1, f): broadcasts with
            # the (B, 1, f) hidden activation inside the segment scan
            masks = jax.tree.map(
                lambda b: jnp.moveaxis(b[idx], 0, 1)[:, :, None], bank)

            def body(carry, _):
                cchs, t, p = carry
                logits, cchs = model_lib.decode_step(
                    params, cfg, cchs, t, p, masks=masks,
                    mla_absorb=mla_absorb)
                nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                return (cchs, nt[:, None], p + 1), nt
            (caches, tok, pos), toks = jax.lax.scan(
                body, (caches, tok, pos), None, length=self.chunk)
            return caches, tok, pos, jnp.moveaxis(toks, 0, 1)   # (B, chunk)

        # old caches are dead once insert/decode return their successors;
        # params and the mask bank live across calls (never donated).
        self._prefill = jax.jit(prefill,
                                donate_argnums=shlib.donate_args())
        self._insert = jax.jit(insert,
                               donate_argnums=shlib.donate_args(0))
        self._decode = jax.jit(decode,
                               donate_argnums=shlib.donate_args(1))

    def _call(self, fn, *args):
        with shlib.serve_kernels_context(**self._kernels):
            return fn(*args)

    def _init_caches(self):
        specs = model_lib.cache_specs(self.cfg, self.B, self.cache_len)
        return jax.tree.map(
            lambda s: (jnp.full(s.shape, -1, s.dtype)
                       if s.dtype == jnp.int32
                       else jnp.zeros(s.shape, s.dtype)), specs)

    # ------------------------------------------------------------------ API
    def submit(self, req: ServeRequest) -> int:
        L = len(req.tokens)
        if L > self.max_prompt_len or L < 1:
            raise ValueError(f"prompt length {L} outside "
                             f"[1, {self.max_prompt_len}]")
        if self.recurrent and L != self.max_prompt_len:
            raise ValueError(
                "recurrent mixers (rwkv/rg-lru) fold right-padding into "
                f"their state: prompts must be exactly {self.max_prompt_len}"
                " tokens for this architecture")
        if not 1 <= req.gen_len <= self.max_gen_len:
            raise ValueError(f"gen_len {req.gen_len} outside "
                             f"[1, {self.max_gen_len}]")
        req.rid = self._next_rid
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _admit(self, slot: int, req: ServeRequest):
        in_use = [s["row"] for s in self.live.values()]
        row = self.bank.row_for(req.fingerprint(),
                                lambda: req.masks, in_use=in_use)
        L = len(req.tokens)
        toks = np.zeros((1, self.max_prompt_len), np.int32)
        toks[0, :L] = np.asarray(req.tokens, np.int32)
        t0 = time.perf_counter()
        first, cache1 = self._call(
            self._prefill, self.params, jnp.asarray(toks),
            jnp.asarray([L], jnp.int32), self.bank.stacked(),
            jnp.asarray(row, jnp.int32))
        first = int(first[0])
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefills"] += 1
        state = {"req": req, "row": row, "out": [first],
                 "remaining": req.gen_len - 1}
        if state["remaining"] > 0:
            self.caches = self._call(self._insert, self.caches, cache1,
                                     jnp.asarray(slot, jnp.int32))
            self.tok[slot, 0] = first
            self.pos[slot] = L
            self.row[slot] = row
            self.live[slot] = state
            return None
        return np.asarray(state["out"], np.int32)     # gen_len == 1

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens (gen_len,)}."""
        results: Dict[int, np.ndarray] = {}
        while self.queue or self.live:
            free = [s for s in range(self.B) if s not in self.live]
            while self.queue and free:
                req = self.queue.popleft()
                done = self._admit(free[0], req)
                if done is not None:
                    results[req.rid] = done
                else:
                    free.pop(0)
            if not self.live:
                continue
            t0 = time.perf_counter()
            caches, tok, pos, toks = self._call(
                self._decode, self.params, self.caches,
                jnp.asarray(self.tok), jnp.asarray(self.pos),
                self.bank.stacked(), jnp.asarray(self.row))
            toks = np.asarray(toks)                    # blocks on the device
            self.stats["decode_s"] += time.perf_counter() - t0
            self.stats["chunks"] += 1
            self.caches = caches
            self.tok = np.array(tok)       # writable host copies
            self.pos = np.array(pos)
            for slot in list(self.live):
                st = self.live[slot]
                take = min(self.chunk, st["remaining"])
                st["out"].extend(toks[slot, :take].tolist())
                st["remaining"] -= take
                self.stats["decode_tokens"] += take
                if st["remaining"] == 0:
                    results[st["req"].rid] = np.asarray(st["out"], np.int32)
                    del self.live[slot]
            # park retired/empty slots at position 0 so their (discarded)
            # decode activity never ring-wraps the cache
            for s in range(self.B):
                if s not in self.live:
                    self.pos[s] = 0
                    self.tok[s, 0] = 0
                    self.row[s] = 0
        return results

    def summary(self) -> dict:
        d = dict(self.stats)
        d["tok_per_s"] = d["decode_tokens"] / max(d["decode_s"], 1e-9)
        d["trace_counts"] = dict(self.trace_counts)
        return d
