"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. The dry-run launcher sets XLA_FLAGS before any jax import to
materialize 512 host placeholder devices.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType (explicit-sharding API) only exists on newer
    # jax; Auto is the default there, so omit it on older versions.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _mesh((data, model), ("data", "model"))
