"""Step builders: train_step / prefill_step / serve_step with shardings.

These are the functions the dry-run lowers and the drivers execute. All take
the mesh through launch.sharding.mesh_context; in/out shardings are derived
from the same rule table the model's internal constraints use.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import sharding as shlib
from repro.models import model as model_lib
from repro.optim import make_optimizer


# ---------------------------------------------------------------------------
# sharding helpers

def _div(n: int, sizes) -> bool:
    t = 1
    for s in sizes:
        t *= s
    return n % t == 0 and n >= t


def batch_spec_sym(mesh, batch: int):
    """'B' if the global batch divides the batch axes, else None (replicate)."""
    ax = shlib.batch_axes(mesh)
    total = 1
    for a in ax:
        total *= mesh.shape[a]
    return "B" if batch % total == 0 and batch >= total else None


def batch_shardings(cfg: ModelConfig, mesh, batch_tree):
    b = None

    def leaf(s):
        sym = batch_spec_sym(mesh, s.shape[0])
        tail = (None,) * (len(s.shape) - 1)
        with shlib.mesh_context(mesh):
            return NamedSharding(mesh, shlib.pspec(sym, *tail))
    return jax.tree.map(leaf, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh, cache_tree, seq_shard=False):
    """Cache leaves are (R, B, ...) stacked. Shard batch; shard the KV-head
    or head-dim axis of attention caches on 'model' when divisible.

    seq_shard=True instead shards the cache *sequence* axis over 'model'
    (cross-device flash-decoding: GSPMD turns the softmax over the sharded
    axis into tiny stat psums instead of gathering the cache — §Perf)."""
    tp = mesh.shape.get("model", 1)

    def leaf(s):
        shape = s.shape
        bsym = batch_spec_sym(mesh, shape[1]) if len(shape) >= 2 else None
        spec = [None, bsym] + [None] * (len(shape) - 2)
        # attention KV cache: (R, B, C, KV, hd)
        if (len(shape) == 5 and shape[3] == cfg.n_kv_heads
                and cfg.head_dim == shape[4]):
            if seq_shard and shape[2] % tp == 0 and shape[2] >= tp:
                spec[2] = "M"
            elif shape[3] % tp == 0:
                spec[3] = "M"
            elif shape[4] % tp == 0:
                spec[4] = "M"    # head-dim sharding (MQA-style decode TP)
        # latent/channel caches (R, B, C, r): MLA c_kv/k_rope, conv history —
        # shard the channel dim (contractions psum; elementwise stays local)
        elif (len(shape) == 4 and s.dtype != jnp.int32
              and shape[3] % tp == 0 and shape[3] >= 2 * tp):
            if seq_shard and shape[2] % tp == 0 and shape[2] >= tp:
                spec[2] = "M"
            else:
                spec[3] = "M"
        elif (seq_shard and len(shape) == 3 and s.dtype == jnp.int32
              and shape[2] % tp == 0 and shape[2] >= tp):
            spec[2] = "M"        # ring slot positions follow the cache
        with shlib.mesh_context(mesh):
            return NamedSharding(mesh, shlib.pspec(*spec))
    return jax.tree.map(leaf, cache_tree)


def opt_state_shardings(mesh, opt_specs, param_shards):
    """Optimizer state mirrors param sharding; scalars replicated."""
    def leaf(path_shape, ps):
        return ps
    # opt state structure: {"m": params-like, "v": params-like, "t": scalar}
    out = {}
    for k, v in opt_specs.items():
        if k in ("m", "v"):
            out[k] = param_shards
        else:
            out[k] = NamedSharding(mesh, P())
    return out


# ---------------------------------------------------------------------------
# step functions

def make_train_step(cfg: ModelConfig, unroll: bool = False,
                    with_masks: bool = False, use_kernels: bool = False,
                    kernel_interpret: Optional[bool] = None):
    """Build the (jit-able) train step.

    use_kernels routes the masked FFN matmuls through the differentiable
    Pallas kernels (kernels/masked_ffn.py custom_vjp — forward and backward
    skip dropped 128-blocks, DESIGN.md §10) by tracing the loss under
    sharding.train_kernels_context. Only meaningful with with_masks=True;
    kernel_interpret defaults to True off-TPU (correctness mode)."""
    opt = make_optimizer(cfg.optimizer)
    accum = max(cfg.grad_accum, 1)
    if kernel_interpret is None:
        from repro.kernels.ops import _default_interpret
        kernel_interpret = _default_interpret()

    def grads_of(params, batch, masks):
        def lf(p):
            return model_lib.loss_fn(p, cfg, batch, masks=masks,
                                     unroll=unroll)
        with shlib.train_kernels_context(ffn=use_kernels,
                                         interpret=kernel_interpret):
            return jax.value_and_grad(lf, has_aux=True)(params)

    def step(params, opt_state, batch, masks=None):
        if accum > 1:
            mb = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def micro(carry, b):
                acc, loss_acc = carry
                (loss, metrics), g = grads_of(params, b, masks)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                   acc, g)
                return (acc, loss_acc + loss), metrics
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            (gsum, loss_sum), metrics = jax.lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grads_of(params, batch, masks)
        params2, opt_state2 = opt.update(grads, opt_state, params,
                                         cfg.learning_rate)
        metrics = dict(metrics, loss=loss)
        return params2, opt_state2, metrics

    if with_masks:
        return step
    return lambda params, opt_state, batch: step(params, opt_state, batch)


def make_prefill_step(cfg: ModelConfig, unroll: bool = False,
                      window_override: Optional[int] = None,
                      cache_len: Optional[int] = None):
    def step(params, batch):
        logits, caches, _ = model_lib.forward_seq(
            params, cfg, batch, window_override=window_override,
            unroll=unroll, want_cache=True, cache_len=cache_len)
        # return only the last-position logits (next-token) + cache
        return logits[:, -1], caches
    return step


def make_serve_step(cfg: ModelConfig, window_override: Optional[int] = None,
                    mla_absorb: bool = False):
    def step(params, caches, token, pos):
        logits, new_caches = model_lib.decode_step(
            params, cfg, caches, token, pos,
            window_override=window_override, mla_absorb=mla_absorb)
        return logits[:, -1], new_caches
    return step


# ---------------------------------------------------------------------------
# lowering assembly

def mask_specs_and_shardings(cfg: ModelConfig, mesh):
    """ShapeDtypeStructs + shardings for FLuID sub-model masks."""
    from repro.core import transformer_hooks as hooks
    with shlib.mesh_context(None):
        masks = hooks.full_masks(cfg)
    spec = jax.tree.map(
        lambda m: jax.ShapeDtypeStruct(m.shape, m.dtype), masks)
    with shlib.mesh_context(mesh):
        shard = jax.tree.map(
            lambda m: NamedSharding(mesh, shlib.guarded_pspec(
                mesh, m.shape, (None,) * (len(m.shape) - 1) + ("M",),
                strict=True)), spec)
    return spec, shard


def shardings_for(cfg: ModelConfig, mesh, mode: str, specs: dict,
                  window_override=None, fsdp: bool = True,
                  cache_seq_shard: bool = False):
    kw_seq_shard = {"on": cache_seq_shard}
    """(in_shardings, out_shardings, arg ShapeDtypeStructs) for jit.lower."""
    param_sp = model_lib.param_specs(cfg)
    kv_ok = cfg.n_kv_heads % mesh.shape.get("model", 1) == 0
    pshard = shlib.param_shardings(param_sp, mesh, kv_shardable=kv_ok,
                                   fsdp=fsdp)

    if mode == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_sp = jax.eval_shape(opt.init, param_sp)
        oshard = opt_state_shardings(mesh, opt_sp, pshard)
        bshard = batch_shardings(cfg, mesh, specs["batch"])
        args = (param_sp, opt_sp, specs["batch"])
        in_sh = (pshard, oshard, bshard)
        out_sh = (pshard, oshard, None)
        return in_sh, out_sh, args

    if mode == "prefill":
        bshard = batch_shardings(cfg, mesh, specs["batch"])
        args = (param_sp, specs["batch"])
        in_sh = (pshard, bshard)
        return in_sh, None, args

    if mode == "decode":
        cshard = cache_shardings(cfg, mesh, specs["caches"],
                                 seq_shard=kw_seq_shard.get("on", False))
        tshard = batch_shardings(cfg, mesh, {"t": specs["token"],
                                             "p": specs["pos"]})
        args = (param_sp, specs["caches"], specs["token"], specs["pos"])
        in_sh = (pshard, cshard, tshard["t"], tshard["p"])
        return in_sh, None, args

    raise ValueError(mode)
