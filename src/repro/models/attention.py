"""GQA/MQA attention with sliding-window support and ring-buffer KV cache.

Three entry points per layer:
  attn_seq(...)     -- full-sequence (train / prefill), query-chunked so the
                       score matrix never exceeds CHUNK x S per head
  attn_decode(...)  -- one new token against a (possibly windowed) ring cache
Cache layout per layer: k,v (B, C, KV, hd); slot positions are carried once
for the whole stack as (B, C) int32 (-1 = empty).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import (decode_cache_mode, serve_kernel_flags,
                                   shard, uniform_pos)
from repro.models.layers import apply_rope, cdtype, dense_init, pdtype

Q_CHUNK = 1024
NEG = -1e30


def init_attention(key, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, d, H, hd, dtype=pdtype(cfg)),
         "wk": dense_init(ks[1], d, d, KV, hd, dtype=pdtype(cfg)),
         "wv": dense_init(ks[2], d, d, KV, hd, dtype=pdtype(cfg)),
         "wo": dense_init(ks[3], H * hd, H, hd, d, dtype=pdtype(cfg))}
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H, hd), pdtype(cfg))
        p["bk"] = jnp.zeros((KV, hd), pdtype(cfg))
        p["bv"] = jnp.zeros((KV, hd), pdtype(cfg))
        p["bo"] = jnp.zeros((d,), pdtype(cfg))
    return p


def _qkv(p, x, cfg: ModelConfig, positions, constrain_heads=True):
    dt = cdtype(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if constrain_heads:
        q = shard(q, "B", None, "M", None)
    return q, k, v


def _expand_kv(k, n_heads):
    """(B,T,KV,hd) -> (B,T,H,hd) by group repeat."""
    KV = k.shape[2]
    if KV == n_heads:
        return k
    return jnp.repeat(k, n_heads // KV, axis=2)


def _sdpa(q, k, v, q_pos, kv_pos, window, scale, causal=True):
    """q:(B,Sq,H,hd) k,v:(B,T,H,hd); positional causal+window mask.

    kv_pos: (T,) or (B,T) absolute positions, -1 = invalid slot.
    """
    scores = jnp.einsum("bqhk,bthk->bhqt", q, k).astype(jnp.float32) * scale
    if kv_pos.ndim == 1:
        kv_b = kv_pos[None, None, None, :]
    else:
        kv_b = kv_pos[:, None, None, :]
    q_b = q_pos[None, None, :, None] if q_pos.ndim == 1 else q_pos[:, None, :, None]
    mask = (kv_b >= 0)
    if causal:
        mask &= (kv_b <= q_b)
    if window is not None:
        mask &= (q_b - kv_b) < window
    scores = jnp.where(mask, scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqt,bthk->bqhk", w.astype(v.dtype), v)
    return out


def attn_seq(p, x, cfg: ModelConfig, positions, window=None, unroll=False,
             kv_override=None, kv_positions=None, causal=True):
    """Full-sequence attention. Returns (out, (k, v)) for cache capture.

    kv_override: (k, v) for cross-attention (no rope re-application here).
    """
    B, S, _ = x.shape
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if kv_override is None:
        q, k, v = _qkv(p, x, cfg, positions)
        kv_pos = positions
    else:
        dt = cdtype(cfg)
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
        if "bq" in p:
            q = q + p["bq"].astype(dt)
        q = shard(q, "B", None, "M", None)
        k, v = kv_override
        kv_pos = kv_positions
    kf = shard(_expand_kv(k, cfg.n_heads), "B", None, "M", None)
    vf = shard(_expand_kv(v, cfg.n_heads), "B", None, "M", None)

    if S <= Q_CHUNK:
        out = _sdpa(q, kf, vf, positions, kv_pos, window, scale, causal)
    else:
        assert S % Q_CHUNK == 0, (S, Q_CHUNK)
        n = S // Q_CHUNK
        qc = q.reshape(B, n, Q_CHUNK, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(n, Q_CHUNK) if positions.ndim == 1 else None

        def body(_, qp):
            qi, pi = qp
            return (), _sdpa(qi, kf, vf, pi, kv_pos, window, scale, causal)
        if not unroll:
            body = jax.checkpoint(body)
        _, oc = jax.lax.scan(body, (), (qc, pc), unroll=(n if unroll else 1))
        out = oc.transpose(1, 0, 2, 3, 4).reshape(B, S, *oc.shape[3:])

    dt = cdtype(cfg)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return shard(y, "B", None, None), (k, v)


def _sdpa_grouped(q, k, v, q_pos, kv_pos, window, scale, causal=True):
    """GQA attention without expanding KV to H heads.
    q: (B,Sq,H,hd); k,v: (B,T,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) * scale
    kv_b = (kv_pos[:, None, None, None, :] if kv_pos.ndim == 2
            else kv_pos[None, None, None, None, :])
    q_b = (q_pos[:, None, None, :, None] if q_pos.ndim == 2
           else q_pos[None, None, None, :, None])
    mask = (kv_b >= 0)
    if causal:
        mask &= (kv_b <= q_b)
    if window is not None:
        mask &= (q_b - kv_b) < window
    s = jnp.where(mask, s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def attn_decode(p, x, cfg: ModelConfig, cache, slot_pos, pos, window=None):
    """One-token decode. x:(B,1,d); cache: {'k','v'} (B,C,KV,hd);
    slot_pos: (B,C) int32; pos: (B,) int32. Returns (y, new_cache, new_slot_pos).
    """
    dt = cdtype(cfg)
    B = x.shape[0]
    C = cache["k"].shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    # decode: leave q unconstrained so GSPMD follows the CACHE's sharding
    # (sequence-sharded cache => partial scores + stat psums, no gathers)
    q, k_new, v_new = _qkv(p, x, cfg, pos[:, None], constrain_heads=False)

    idx = (pos % C).astype(jnp.int32)                       # (B,)
    if uniform_pos():
        # synchronized batch: one slot write, no full-cache rewrite
        i0 = idx[0]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, i0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, i0, 1)
        new_slots = jax.lax.dynamic_update_slice_in_dim(
            slot_pos, pos[:, None], i0, 1)
    else:
        # boolean select keeps the cache dtype (arithmetic blends get
        # upcast to f32 by XLA -> 4x the cache rewrite traffic)
        upd = (jnp.arange(C, dtype=jnp.int32)[None, :] == idx[:, None])
        ck = jnp.where(upd[:, :, None, None], k_new, cache["k"])
        cv = jnp.where(upd[:, :, None, None], v_new, cache["v"])
        new_slots = jnp.where(upd, pos[:, None], slot_pos)

    flags = serve_kernel_flags()
    if (flags["attn"] and window is None and decode_cache_mode() != "seq"):
        # Pallas flash-decode (kernels/decode_gqa.py). Valid when slots
        # [0, pos] hold the live positions contiguously — i.e. the cache has
        # never ring-wrapped — which launch/serving.py guarantees by sizing
        # cache_len >= prompt_len + gen_len. lengths = pos + 1 then masks
        # exactly the same set as the slot-based _sdpa mask.
        from repro.kernels.decode_gqa import decode_gqa
        out = decode_gqa(q[:, 0], ck, cv, pos + 1,
                         interpret=flags["interpret"])[:, None]
    elif decode_cache_mode() == "seq":
        # pin the cache sequence axis to the model axis: scores stay local
        # per C-shard, softmax stats + out psum are the only collectives.
        # Grouped GQA einsum (no KV->H expansion): the cache is the largest
        # tensor in decode — never materialize a repeated copy of it.
        ck = shard(ck, "B", "M", None, None)
        cv = shard(cv, "B", "M", None, None)
        out = _sdpa_grouped(q, ck, cv, pos[:, None], new_slots, window,
                            scale)
        out = shard(out, "B", None, None, None)
    else:
        kf = _expand_kv(ck, cfg.n_heads)
        vf = _expand_kv(cv, cfg.n_heads)
        out = _sdpa(q, kf, vf, pos[:, None], new_slots, window, scale)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return shard(y, "B", None, None), {"k": ck, "v": cv}, new_slots


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    kvd = jnp.dtype(cfg.dtype)
    return {"k": jax.ShapeDtypeStruct((batch, cache_len, cfg.n_kv_heads,
                                       cfg.head_dim), kvd),
            "v": jax.ShapeDtypeStruct((batch, cache_len, cfg.n_kv_heads,
                                       cfg.head_dim), kvd)}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len))
