"""Mixture-of-Experts FFN: top-k router + ragged_dot grouped matmul.

Parallelism: tensor-parallel experts — every device holds *all* experts with a
1/16 slice of the expert hidden dim ("model" axis). Token dispatch (top-k,
sort, ragged grouped matmul) is therefore local to each data shard; the only
collective is the same all-reduce a dense TP FFN needs. This sidesteps
all-to-all dispatch entirely (see EXPERIMENTS.md §Perf for the comparison
discussion) and is implemented with shard_map so ragged_dot never has to be
GSPMD-partitioned.

Invariant-Dropout hooks:
  expert_mask  (E,)   -- 0 drops a whole expert (router logit -> -inf)
  neuron_mask  (E, f) -- 0 drops an expert-hidden unit
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import batch_axes, current_mesh
from repro.models.layers import GATED, cdtype, dense_init, init_ffn, apply_ffn, pdtype

from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def init_moe(key, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_ff
    ks = jax.random.split(key, 6)
    p = {"router": dense_init(ks[0], d, d, E, dtype=jnp.float32),
         "w_in": dense_init(ks[1], d, E, d, f, dtype=pdtype(cfg)),
         "w_out": dense_init(ks[2], f, E, f, d, dtype=pdtype(cfg))}
    if cfg.ffn_kind in GATED:
        p["w_gate"] = dense_init(ks[3], d, E, d, f, dtype=pdtype(cfg))
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(ks[4], cfg, d_ff=cfg.n_shared_experts * f)
    if cfg.dense_ff_residual:
        p["dense"] = init_ffn(ks[5], cfg, d_ff=cfg.d_ff)
    return p


CAPACITY_FACTOR = 1.25


def _route(p, x2d, cfg: ModelConfig, expert_mask):
    T, _ = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, :] > 0, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    topv, topi = jax.lax.top_k(probs, k)                        # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    flat_e = topi.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    tok = order // k
    gs = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    w = jnp.take(topv.reshape(T * k), order)
    # load-balance auxiliary loss (Switch-style)
    frac = gs.astype(jnp.float32) / jnp.maximum(T * k, 1)
    aux = E * jnp.sum(frac * probs.mean(axis=0))
    return order, tok, gs, w, jnp.take(flat_e, order), aux


def _expert_act(p, h, g, cfg, dt):
    if g is not None:
        return jax.nn.silu(g) * h
    return jax.nn.gelu(h)


def _moe_tokens(p, x2d, cfg: ModelConfig, neuron_mask, expert_mask,
                stream_axis=None):
    """Local MoE over flat tokens x2d: (T, d).

    Default impl "capacity": tokens are scattered into per-expert buckets of
    size cap = ceil(T*k/E * CAPACITY_FACTOR) and processed with one dense
    (E, cap, d) x (E, d, f) einsum — the XLA-portable grouped matmul
    (overflow tokens drop, standard capacity semantics). impl "ragged" uses
    jax.lax.ragged_dot (efficient on TPU; XLA:CPU expands it densely, so the
    dry-run uses capacity).
    """
    dt = cdtype(cfg)
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    order, tok, gs, w, row_e, aux = _route(p, x2d, cfg, expert_mask)
    xs = jnp.take(x2d, tok, axis=0)                             # (T*k, d)

    if cfg.moe_impl == "ragged":
        h = jax.lax.ragged_dot(xs, p["w_in"].astype(dt), gs)
        g = (jax.lax.ragged_dot(xs, p["w_gate"].astype(dt), gs)
             if "w_gate" in p else None)
        h = _expert_act(p, h, g, cfg, dt)
        if neuron_mask is not None:
            h = h * jnp.take(neuron_mask, row_e, axis=0).astype(dt)
        out = jax.lax.ragged_dot(h, p["w_out"].astype(dt), gs)  # (T*k, d)
        y = jnp.zeros((T, d), dt).at[tok].add(out * w[:, None].astype(dt))
        return y, aux

    cap = max(int(np.ceil(T * k / E * cfg.moe_capacity_factor)), 1)
    offsets = jnp.cumsum(gs) - gs                               # (E,)
    rank = jnp.arange(T * k, dtype=jnp.int32) - jnp.take(offsets, row_e)
    keep = rank < cap
    buckets = jnp.zeros((E, cap, d), dt)
    buckets = buckets.at[row_e, jnp.where(keep, rank, cap - 1)].set(
        jnp.where(keep[:, None], xs, 0).astype(dt), mode="drop")

    def expert_matmul(bk, wi, wg, wo, nm):
        h = jnp.einsum("ecd,edf->ecf", bk, wi.astype(dt))
        g = (jnp.einsum("ecd,edf->ecf", bk, wg.astype(dt))
             if wg is not None else None)
        h = _expert_act(p, h, g, cfg, dt)
        if nm is not None:
            h = h * nm[:, None, :].astype(dt)
        return jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))

    if stream_axis is not None:
        # Weights arrive (n_shards, ec, d, f_loc) with the shard dim mapped to
        # the FSDP axes: each scan step broadcasts ONE shard's expert chunk
        # (psum of a masked copy) so the resident gathered working set is
        # E/n_shards experts instead of all E (Arctic: 1.7 GiB vs 27 GiB).
        ax_name, nsh = stream_axis
        ec_ = p["w_in"].shape[1]
        if isinstance(ax_name, tuple):
            didx = jnp.zeros((), jnp.int32)
            for a in ax_name:
                didx = didx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        else:
            didx = jax.lax.axis_index(ax_name)

        def sbody(_, s):
            sel = (didx == s)
            def bcast(t):
                return jax.lax.psum(jnp.where(sel, t, jnp.zeros_like(t)),
                                    ax_name)
            wi = bcast(p["w_in"])[0]
            wo = bcast(p["w_out"])[0]
            wg = bcast(p["w_gate"])[0] if "w_gate" in p else None
            nm = (bcast(neuron_mask)[0] if neuron_mask is not None else None)
            bk = jax.lax.dynamic_slice_in_dim(buckets, s * ec_, ec_, axis=0)
            return (), expert_matmul(bk, wi, wg, wo, nm)
        _, out_c = jax.lax.scan(jax.checkpoint(sbody), (),
                                jnp.arange(nsh, dtype=jnp.int32))
        out_b = out_c.reshape(E, cap, d)
        out = out_b[row_e, jnp.clip(rank, 0, cap - 1)]
        out = jnp.where(keep[:, None], out, 0)
        y = jnp.zeros((T, d), dt).at[tok].add(out * w[:, None].astype(dt))
        return y, aux

    ec = cfg.moe_expert_chunk
    if ec and E > ec and E % ec == 0:
        # scan over expert chunks: bounds the gathered-weight working set to
        # ec experts at a time (vital at Arctic scale: 128 experts x 7168 x
        # 4864 would otherwise materialize ~27 GiB per layer)
        nec = E // ec
        wg_r = (p["w_gate"].reshape(nec, ec, d, -1) if "w_gate" in p
                else None)
        nm_r = (neuron_mask.reshape(nec, ec, -1) if neuron_mask is not None
                else None)
        xs_scan = (buckets.reshape(nec, ec, cap, d),
                   p["w_in"].reshape(nec, ec, d, -1),
                   p["w_out"].reshape(nec, ec, -1, d))

        def ebody(_, args):
            bk, wi, wo = args[:3]
            wg = args[3] if wg_r is not None else None
            nm = args[-1] if nm_r is not None else None
            return (), expert_matmul(bk, wi, wg, wo, nm)
        extra = tuple(t for t in (wg_r, nm_r) if t is not None)
        _, out_c = jax.lax.scan(jax.checkpoint(ebody), (), xs_scan + extra)
        out_b = out_c.reshape(E, cap, d)
    else:
        out_b = expert_matmul(buckets, p["w_in"],
                              p.get("w_gate"), p["w_out"], neuron_mask)
    out = out_b[row_e, jnp.clip(rank, 0, cap - 1)]              # (T*k, d)
    out = jnp.where(keep[:, None], out, 0)
    y = jnp.zeros((T, d), dt).at[tok].add(out * w[:, None].astype(dt))
    return y, aux


def _moe_local(p, x, neuron_mask, expert_mask, cfg: ModelConfig,
               axis_names=(), stream_axis=None):
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    T = B * S
    ck = cfg.moe_token_chunk
    if T <= ck:
        y, aux = _moe_tokens(p, x2d, cfg, neuron_mask, expert_mask,
                             stream_axis)
    else:
        while T % ck != 0:
            ck //= 2
        nck = T // ck

        def body(_, xi):
            yi, auxi = _moe_tokens(p, xi, cfg, neuron_mask, expert_mask,
                                   stream_axis)
            return (), (yi, auxi)
        _, (y, auxs) = jax.lax.scan(jax.checkpoint(body), (),
                                    x2d.reshape(nck, ck, d))
        y = y.reshape(T, d)
        aux = auxs.mean()
    y = y.reshape(B, S, d)
    if axis_names:
        if "model" in axis_names:
            y = jax.lax.psum(y, "model")        # partial sums over f shards
        aux = jax.lax.pmean(aux, axis_names)
    if "shared" in p:
        y = y + apply_ffn(p["shared"], x, cfg)
    if "dense" in p:
        y = y + apply_ffn(p["dense"], x, cfg)
    return y, aux


def apply_moe(p, x, cfg: ModelConfig, neuron_mask=None, expert_mask=None):
    """x: (B,S,d). Returns (y, aux_loss)."""
    mesh = current_mesh()
    if mesh is None:
        return _moe_local(p, x, neuron_mask, expert_mask, cfg)

    baxes = batch_axes(mesh)
    names = tuple(mesh.axis_names)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    if x.shape[0] % nb != 0 or x.shape[0] < nb:
        bspec = None    # tiny batch (e.g. long-context decode): replicate
    else:
        bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    xspec = P(bspec, None, None)
    pspecs = {"router": P(None, None),
              "w_in": P(None, None, "model"),
              "w_out": P(None, "model", None)}
    if "w_gate" in p:
        pspecs["w_gate"] = P(None, None, "model")
    for extra in ("shared", "dense"):
        if extra in p:
            pspecs[extra] = {k: (P(None, "model") if k in ("w_in", "w_gate", "b_in", "b_gate")
                                 else P("model", None) if k == "w_out"
                                 else P(None))
                             for k in p[extra]}
            for k in p[extra]:
                if k in ("b_in", "b_gate"):
                    pspecs[extra][k] = P("model")
                elif k == "b_out":
                    pspecs[extra][k] = P(None)
    # shard the grouped-matmul core only; shared/dense FFNs run under GSPMD
    core = {k: p[k] for k in ("router", "w_in", "w_out", "w_gate") if k in p}
    core_specs = {k: pspecs[k] for k in core}
    nm_spec = P(None, "model") if neuron_mask is not None else None
    em_spec = P(None) if expert_mask is not None else None

    E = cfg.n_experts
    fsdp_axes = baxes
    dsz = 1
    for a in fsdp_axes:
        dsz *= mesh.shape[a]
    stream_axis = None
    if (cfg.moe_weight_stream and fsdp_axes
            and E % dsz == 0 and dsz > 1):
        sax = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
        stream_axis = (sax, dsz)
        ec = E // dsz
        d = cfg.d_model
        core = dict(core)
        core["w_in"] = core["w_in"].reshape(dsz, ec, d, -1)
        core["w_out"] = core["w_out"].reshape(dsz, ec, -1, d)
        core_specs = dict(core_specs)
        core_specs["w_in"] = P(sax, None, None, "model")
        core_specs["w_out"] = P(sax, None, "model", None)
        if "w_gate" in core:
            core["w_gate"] = core["w_gate"].reshape(dsz, ec, d, -1)
            core_specs["w_gate"] = P(sax, None, None, "model")
        if neuron_mask is not None:
            neuron_mask = neuron_mask.reshape(dsz, ec, -1)
            nm_spec = P(sax, None, "model")

    def fn(cp, xl, nm, em):
        return _moe_local(cp, xl, nm, em, cfg, axis_names=names,
                          stream_axis=stream_axis)

    y, aux = shard_map(
        fn, mesh,
        in_specs=(core_specs, xspec, nm_spec, em_spec),
        out_specs=(xspec, P()),
    )(core, x, neuron_mask, expert_mask)

    if "shared" in p:
        y = y + apply_ffn(p["shared"], x, cfg)
    if "dense" in p:
        y = y + apply_ffn(p["dense"], x, cfg)
    return y, aux
