"""Paper-scale models for the faithful FLuID reproduction.

CNN (FEMNIST), VGG-9 (CIFAR10), 2-layer LSTM (Shakespeare) — exactly the
model families of the paper's evaluation (Section 6), in pure JAX.

Each model exposes:
  init(key)        -> params (nested dict)
  apply(params, x) -> logits
  UNIT_SPECS       -> droppable neuron groups for core/submodel.py

Unit-spec grammar: a group is
  {"name": str, "size": n,
   "out": [(path, axis, tile_factor)],   # producer arrays (weights making the neuron)
   "in":  [(path, axis, tile_factor)]}   # consumer arrays (weights reading it)
tile_factor handles structured axes: conv->FC flatten (channel-fastest, factor
= #spatial positions) and LSTM gate blocks (factor=4). Axis length must equal
size * tile_factor; kept indices expand to {t*size + i}.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _dense(key, fan_in, shape):
    return jax.random.normal(key, shape) * (1.0 / math.sqrt(fan_in))


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# FEMNIST CNN: 2x [5x5 conv + 2x2 maxpool], FC-120, softmax-62 (paper §6)

class FemnistCNN:
    num_classes = 62
    input_shape = (28, 28, 1)

    UNIT_SPECS = [
        {"name": "conv1", "size": 16,
         "out": [("conv1/w", 3, 1), ("conv1/b", 0, 1)],
         "in": [("conv2/w", 2, 1)]},
        {"name": "conv2", "size": 64,
         "out": [("conv2/w", 3, 1), ("conv2/b", 0, 1)],
         "in": [("fc1/w", 0, 49)]},          # 7x7 spatial positions
        {"name": "fc1", "size": 120,
         "out": [("fc1/w", 1, 1), ("fc1/b", 0, 1)],
         "in": [("out/w", 0, 1)]},
    ]

    @staticmethod
    def init(key):
        ks = jax.random.split(key, 4)
        return {
            "conv1": {"w": _dense(ks[0], 25, (5, 5, 1, 16)),
                      "b": jnp.zeros((16,), jnp.float32)},
            "conv2": {"w": _dense(ks[1], 25 * 16, (5, 5, 16, 64)),
                      "b": jnp.zeros((64,), jnp.float32)},
            "fc1": {"w": _dense(ks[2], 7 * 7 * 64, (7 * 7 * 64, 120)),
                    "b": jnp.zeros((120,), jnp.float32)},
            "out": {"w": _dense(ks[3], 120, (120, 62)),
                    "b": jnp.zeros((62,), jnp.float32)},
        }

    @staticmethod
    def apply(params, x):
        x = jax.nn.relu(_conv(x, params["conv1"]["w"], params["conv1"]["b"]))
        x = _pool(x)
        x = jax.nn.relu(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
        x = _pool(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]


# ---------------------------------------------------------------------------
# VGG-9 for CIFAR10 (paper §6: 6 conv 3x3 [32,32,64,64,128,128] + FC512 + FC256)

class Vgg9:
    num_classes = 10
    input_shape = (32, 32, 3)

    _CONVS = [("c1a", 3, 32), ("c1b", 32, 32), ("c2a", 32, 64),
              ("c2b", 64, 64), ("c3a", 64, 128), ("c3b", 128, 128)]

    UNIT_SPECS = (
        [{"name": n, "size": co,
          "out": [(f"{n}/w", 3, 1), (f"{n}/b", 0, 1)],
          "in": [(f"{nx}/w", 2, 1)]}
         for (n, ci, co), (nx, _, _) in zip(_CONVS[:-1], _CONVS[1:])]
        + [{"name": "c3b", "size": 128,
            "out": [("c3b/w", 3, 1), ("c3b/b", 0, 1)],
            "in": [("fc1/w", 0, 16)]},       # 4x4 spatial positions
           {"name": "fc1", "size": 512,
            "out": [("fc1/w", 1, 1), ("fc1/b", 0, 1)],
            "in": [("fc2/w", 0, 1)]},
           {"name": "fc2", "size": 256,
            "out": [("fc2/w", 1, 1), ("fc2/b", 0, 1)],
            "in": [("out/w", 0, 1)]}])

    @staticmethod
    def init(key):
        ks = jax.random.split(key, 9)
        p = {}
        for i, (n, ci, co) in enumerate(Vgg9._CONVS):
            p[n] = {"w": _dense(ks[i], 9 * ci, (3, 3, ci, co)),
                    "b": jnp.zeros((co,), jnp.float32)}
        p["fc1"] = {"w": _dense(ks[6], 4 * 4 * 128, (4 * 4 * 128, 512)),
                    "b": jnp.zeros((512,), jnp.float32)}
        p["fc2"] = {"w": _dense(ks[7], 512, (512, 256)),
                    "b": jnp.zeros((256,), jnp.float32)}
        p["out"] = {"w": _dense(ks[8], 256, (256, 10)),
                    "b": jnp.zeros((10,), jnp.float32)}
        return p

    @staticmethod
    def apply(params, x):
        for i, (n, _, _) in enumerate(Vgg9._CONVS):
            x = jax.nn.relu(_conv(x, params[n]["w"], params[n]["b"]))
            if i % 2 == 1:
                x = _pool(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]


# ---------------------------------------------------------------------------
# Shakespeare 2-layer LSTM classifier, 128 hidden units (paper §6)

class ShakespeareLSTM:
    vocab = 80
    embed_dim = 8
    hidden = 128
    num_classes = 80
    seq_len = 20

    UNIT_SPECS = [
        {"name": "lstm1", "size": 128,
         "out": [("lstm1/W", 1, 4), ("lstm1/U", 1, 4), ("lstm1/b", 0, 4)],
         "in": [("lstm1/U", 0, 1), ("lstm2/W", 0, 1)]},
        {"name": "lstm2", "size": 128,
         "out": [("lstm2/W", 1, 4), ("lstm2/U", 1, 4), ("lstm2/b", 0, 4)],
         "in": [("lstm2/U", 0, 1), ("out/w", 0, 1)]},
    ]

    @staticmethod
    def init(key):
        ks = jax.random.split(key, 6)
        V, E, H = (ShakespeareLSTM.vocab, ShakespeareLSTM.embed_dim,
                   ShakespeareLSTM.hidden)
        return {
            "embed": _dense(ks[0], E, (V, E)),
            "lstm1": {"W": _dense(ks[1], E, (E, 4 * H)),
                      "U": _dense(ks[2], H, (H, 4 * H)),
                      "b": jnp.zeros((4 * H,), jnp.float32)},
            "lstm2": {"W": _dense(ks[3], H, (H, 4 * H)),
                      "U": _dense(ks[4], H, (H, 4 * H)),
                      "b": jnp.zeros((4 * H,), jnp.float32)},
            "out": {"w": _dense(ks[5], H, (H, V)),
                    "b": jnp.zeros((V,), jnp.float32)},
        }

    @staticmethod
    def _lstm(p, xs):
        """xs: (B,S,in). Hidden size inferred from U (supports sub-models)."""
        H = p["U"].shape[0]
        B = xs.shape[0]

        def step(carry, x):
            h, c = carry
            z = x @ p["W"] + h @ p["U"] + p["b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h
        init = (jnp.zeros((B, H), xs.dtype), jnp.zeros((B, H), xs.dtype))
        (_, _), hs = jax.lax.scan(step, init, xs.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2)

    @staticmethod
    def apply(params, x):
        """x: (B,S) int32 char ids -> logits for next char (last position)."""
        e = jnp.take(params["embed"], x, axis=0)
        h = ShakespeareLSTM._lstm(params["lstm1"], e)
        h = ShakespeareLSTM._lstm(params["lstm2"], h)
        return h[:, -1] @ params["out"]["w"] + params["out"]["b"]


# ---------------------------------------------------------------------------
# Population-scale probe model: 32-dim vector in, one droppable hidden layer.
# Small on purpose — a 5k-client cohort's stacked deltas stay a few hundred
# MB short of anything interesting, so benchmarks/population_bench.py can
# sweep cohort sizes from a 100k-client store on one host.

class SynthMLP:
    num_classes = 10
    input_shape = (32,)

    UNIT_SPECS = [
        {"name": "fc1", "size": 64,
         "out": [("fc1/w", 1, 1), ("fc1/b", 0, 1)],
         "in": [("out/w", 0, 1)]},
    ]

    @staticmethod
    def init(key):
        ks = jax.random.split(key, 2)
        return {
            "fc1": {"w": _dense(ks[0], 32, (32, 64)),
                    "b": jnp.zeros((64,), jnp.float32)},
            "out": {"w": _dense(ks[1], 64, (64, 10)),
                    "b": jnp.zeros((10,), jnp.float32)},
        }

    @staticmethod
    def apply(params, x):
        h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        return h @ params["out"]["w"] + params["out"]["b"]


MODELS = {"femnist_cnn": FemnistCNN, "cifar_vgg9": Vgg9,
          "shakespeare_lstm": ShakespeareLSTM, "synth_mlp": SynthMLP}
