"""Shared layer primitives: norms, RoPE, embeddings, FFN variants."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.sharding import (serve_kernel_flags, shard,
                                   train_kernel_flags)


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers

def dense_init(key, fan_in, *shape, dtype):
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms

def init_norm(cfg: ModelConfig, dim=None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), pdtype(cfg))}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), pdtype(cfg))
    return p


def apply_norm(p, x, cfg: ModelConfig, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))


def apply_rope(x, positions, theta: float, has_heads: bool = True):
    """x: (..., S, H, hd) if has_heads else (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if has_heads:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings

def init_embed(key, cfg: ModelConfig):
    v = cfg.padded_vocab
    p = {"embed": dense_init(key, cfg.d_model, v, cfg.d_model,
                             dtype=pdtype(cfg))}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["lm_head"] = dense_init(k2, cfg.d_model, cfg.d_model, v,
                                  dtype=pdtype(cfg))
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    emb = shard(p["embed"].astype(cdtype(cfg)), "M", None)
    x = jnp.take(emb, tokens, axis=0)
    return shard(x, "B", None, None)


def lm_logits(p, x, cfg: ModelConfig):
    w = p.get("lm_head")
    if w is None:
        w = p["embed"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(cdtype(cfg)))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, "B", None, "M")


# ---------------------------------------------------------------------------
# FFN

GATED = {"swiglu", "gelu_gated"}


def init_ffn(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d, d, f, dtype=pdtype(cfg)),
         "w_out": dense_init(ks[1], f, f, d, dtype=pdtype(cfg))}
    if cfg.ffn_kind in GATED:
        p["w_gate"] = dense_init(ks[2], d, d, f, dtype=pdtype(cfg))
    if cfg.use_bias:
        p["b_in"] = jnp.zeros((f,), pdtype(cfg))
        p["b_out"] = jnp.zeros((d,), pdtype(cfg))
        if cfg.ffn_kind in GATED:
            p["b_gate"] = jnp.zeros((f,), pdtype(cfg))
    return p


def _act(h, kind):
    if kind in ("swiglu",):
        return jax.nn.silu(h)
    if kind in ("gelu", "gelu_gated"):
        return jax.nn.gelu(h)
    if kind == "relu":
        return jax.nn.relu(h)
    if kind == "relu2":
        return jnp.square(jax.nn.relu(h))
    raise ValueError(kind)


_KERNEL_ACT = {"swiglu": ("silu", True), "gelu_gated": ("gelu", True),
               "gelu": ("gelu", False), "relu": ("relu", False),
               "relu2": ("relu2", False)}


def _ffn_kernel_ok(p, x, cfg, neuron_mask) -> bool:
    """Pallas masked_ffn_batch applies on the single-token decode shape:
    per-request masks, no biases, 128-aligned hidden dim."""
    return (x.ndim == 3 and x.shape[1] == 1
            and neuron_mask is not None and neuron_mask.ndim == 3
            and "b_in" not in p
            and p["w_in"].shape[1] % 128 == 0
            and cfg.ffn_kind in _KERNEL_ACT)


def _ffn_train_kernel_ok(p, x, cfg, neuron_mask) -> bool:
    """The differentiable masked kernel applies on the (B, S, d) train shape
    with one shared (f,) layer mask, no biases, 128-aligned hidden dim."""
    return (x.ndim == 3 and neuron_mask is not None and neuron_mask.ndim == 1
            and "b_in" not in p
            and p["w_in"].shape[1] % 128 == 0
            and cfg.ffn_kind in _KERNEL_ACT)


def apply_ffn(p, x, cfg: ModelConfig, neuron_mask=None):
    """FFN with optional neuron mask (Invariant-Dropout masked sub-model).

    neuron_mask: (f,) 0/1 — masked neurons contribute nothing; identical in
    math to physically extracting the sub-model columns. The serving decode
    step passes per-request masks (B, 1, f) instead and may opt into the
    tile-skipping Pallas kernel via sharding.serve_kernels_context; the
    train step opts into the differentiable custom_vjp kernel (forward AND
    backward skip dropped blocks, DESIGN.md §10) via
    sharding.train_kernels_context.
    """
    dt = cdtype(cfg)
    tflags = train_kernel_flags()
    if tflags["ffn"] and _ffn_train_kernel_ok(p, x, cfg, neuron_mask):
        from repro.kernels.masked_ffn import masked_ffn_batch
        act, gated = _KERNEL_ACT[cfg.ffn_kind]
        B, S, d = x.shape
        f = p["w_in"].shape[1]
        rm = jnp.broadcast_to(neuron_mask.astype(dt)[None, :], (B * S, f))
        y = masked_ffn_batch(
            x.reshape(B * S, d).astype(dt), p["w_in"].astype(dt),
            p["w_out"].astype(dt), rm,
            w_gate=p["w_gate"].astype(dt) if gated else None,
            act=act, interpret=tflags["interpret"])
        return shard(y.reshape(B, S, d), "B", None, None)
    flags = serve_kernel_flags()
    if flags["ffn"] and _ffn_kernel_ok(p, x, cfg, neuron_mask):
        from repro.kernels.masked_ffn import masked_ffn_batch
        act, gated = _KERNEL_ACT[cfg.ffn_kind]
        B, _, d = x.shape
        y = masked_ffn_batch(
            x.reshape(B, d).astype(dt), p["w_in"].astype(dt),
            p["w_out"].astype(dt), neuron_mask.reshape(B, -1),
            w_gate=p["w_gate"].astype(dt) if gated else None,
            act=act, interpret=flags["interpret"])
        return shard(y.reshape(B, 1, d), "B", None, None)
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dt))
    if "b_in" in p:
        h = h + p["b_in"].astype(dt)
    if cfg.ffn_kind in GATED:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
        if "b_gate" in p:
            g = g + p["b_gate"].astype(dt)
        h = _act(g, cfg.ffn_kind) * h
    else:
        h = _act(h, cfg.ffn_kind)
    h = shard(h, "B", None, "M")
    if neuron_mask is not None:
        h = h * neuron_mask.astype(dt)
    out = jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt))
    if "b_out" in p:
        out = out + p["b_out"].astype(dt)
    return shard(out, "B", None, None)


# ---------------------------------------------------------------------------
# losses

def softmax_xent(logits, targets, mask=None, vocab_size=None):
    """Mean cross-entropy; ignores padded vocab tail via target clamp."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
