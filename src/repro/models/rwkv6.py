"""RWKV-6 "Finch": data-dependent decay linear attention + channel mix.

TPU adaptation: training/prefill use a *chunked* formulation — intra-chunk
work is a batched (c, c, N) contraction (matrix units), inter-chunk state is
a short scan — instead of a length-S sequential scan. All decay products are
expressed as exp(sum-of-logs differences) that are provably <= 0, so the
chunked path never overflows regardless of decay magnitude.

Recurrence per head (key/value dim N):
  S_t = diag(w_t) S_{t-1} + k_t v_t^T
  y_t = r_t^T S_{t-1} + (r_t . (u ⊙ k_t)) v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard
from repro.models.layers import cdtype, dense_init, pdtype

LORA_MIX = 32
LORA_DECAY = 64


def init_tmix(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    pd = pdtype(cfg)
    p = {
        "mix_x": jnp.full((d,), 0.5, pd),
        "mix_r": jnp.full((d,), 0.5, pd),
        "mix_k": jnp.full((d,), 0.5, pd),
        "mix_v": jnp.full((d,), 0.5, pd),
        "mix_w": jnp.full((d,), 0.5, pd),
        "mix_g": jnp.full((d,), 0.5, pd),
        "lora_mix_a": dense_init(ks[0], d, d, 5 * LORA_MIX, dtype=pd),
        "lora_mix_b": (jnp.zeros((5, LORA_MIX, d), pd)
                       + 1e-3 * jax.random.normal(ks[1], (5, LORA_MIX, d), pd)),
        "w_decay": jnp.linspace(-6.0, -1.0, d, dtype=pd),  # w0: resting decay
        "lora_w_a": dense_init(ks[2], d, d, LORA_DECAY, dtype=pd),
        "lora_w_b": 1e-3 * jax.random.normal(ks[3], (LORA_DECAY, d), pd),
        "w_u": jax.random.normal(ks[4], (d,), pd) * 0.1,  # bonus
        "w_r": dense_init(ks[5], d, d, d, dtype=pd),
        "w_k": dense_init(ks[6], d, d, d, dtype=pd),
        "w_v": dense_init(ks[7], d, d, d, dtype=pd),
        "w_g": dense_init(ks[8], d, d, d, dtype=pd),
        "w_o": dense_init(ks[9], d, d, d, dtype=pd),
        "ln_scale": jnp.ones((d,), pd),
        "ln_bias": jnp.zeros((d,), pd),
    }
    return p


def init_cmix(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    pd = pdtype(cfg)
    return {"mix_k": jnp.full((d,), 0.5, pd),
            "mix_r": jnp.full((d,), 0.5, pd),
            "w_in": dense_init(ks[0], d, d, f, dtype=pd),
            "w_out": dense_init(ks[1], f, f, d, dtype=pd),
            "w_r": dense_init(ks[2], d, d, d, dtype=pd)}


# ---------------------------------------------------------------------------


def _ddlerp(p, x, x_prev, cfg):
    """Data-dependent token-shift mixing -> (xr, xk, xv, xw, xg)."""
    dt = cdtype(cfg)
    xx = x_prev - x
    sx = x + xx * p["mix_x"].astype(dt)
    z = jnp.tanh(jnp.einsum("...d,dr->...r", sx, p["lora_mix_a"].astype(dt)))
    z = z.reshape(*z.shape[:-1], 5, LORA_MIX)
    delta = jnp.einsum("...fr,frd->...fd", z, p["lora_mix_b"].astype(dt))
    outs = []
    for i, nm in enumerate(("mix_r", "mix_k", "mix_v", "mix_w", "mix_g")):
        m = p[nm].astype(dt) + delta[..., i, :]
        outs.append(x + xx * m)
    return outs


def _rkvwg(p, x, x_prev, cfg):
    dt = cdtype(cfg)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev, cfg)
    r = jnp.einsum("...d,de->...e", xr, p["w_r"].astype(dt))
    k = jnp.einsum("...d,de->...e", xk, p["w_k"].astype(dt))
    v = jnp.einsum("...d,de->...e", xv, p["w_v"].astype(dt))
    g = jnp.einsum("...d,de->...e", xg, p["w_g"].astype(dt))
    ww = (p["w_decay"].astype(jnp.float32)
          + jnp.tanh(jnp.einsum("...d,dr->...r", xw,
                                p["lora_w_a"].astype(dt))).astype(jnp.float32)
          @ p["lora_w_b"].astype(jnp.float32))
    logw = -jnp.exp(ww)                                   # log decay, < 0
    return r, k, v, g, logw


def _heads(x, H, N):
    return x.reshape(*x.shape[:-1], H, N)


def _group_norm(p, y, H, N, eps=1e-5):
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(*y.shape[:-2], H * N)
    return (yn * p["ln_scale"].astype(jnp.float32)
            + p["ln_bias"].astype(jnp.float32))


def _chunk_core(r, k, v, logw, u, S0, chunk_dtype=jnp.float32):
    """One chunk. r,k,v: (B,c,H,N); logw: (B,c,H,N) fp32; S0: (B,H,N,N) fp32.
    Returns (y: (B,c,H,N) fp32, S1). chunk_dtype controls the decay-tensor
    einsum precision (all exponents are <= 0, so bf16 only loses mantissa on
    already-damped terms)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    L_inc = jnp.cumsum(logw, axis=1)                      # inclusive
    L_exc = L_inc - logw                                  # exclusive
    L_tot = L_inc[:, -1:]                                 # (B,1,H,N)

    # inter-chunk: y_t += (r_t * exp(L_exc_t)) @ S0
    q_dec = rf * jnp.exp(L_exc)
    y = jnp.einsum("bchn,bhnm->bchm", q_dec, S0)

    # intra-chunk strict-lower part: D[t,j,n] = exp(L_exc[t] - L_inc[j]) <= 1
    Dlog = L_exc[:, :, None] - L_inc[:, None, :]          # (B,c,c,H,N)
    c = r.shape[1]
    tri = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
    D = jnp.where(tri[None, :, :, None, None], jnp.exp(Dlog),
                  0.0).astype(chunk_dtype)
    scores = jnp.einsum("bthn,bjhn,btjhn->bthj", rf.astype(chunk_dtype),
                        kf.astype(chunk_dtype), D).astype(jnp.float32)
    y = y + jnp.einsum("bthj,bjhm->bthm", scores, vf)

    # diagonal bonus term
    diag = jnp.einsum("bthn,bthn->bth", rf, u[None, None] * kf)
    y = y + diag[..., None] * vf

    # state update: S1 = exp(L_tot) ⊙ S0 + sum_j exp(L_tot - L_inc_j) k_j v_j^T
    k_hat = kf * jnp.exp(L_tot - L_inc)
    S1 = jnp.exp(L_tot)[:, 0, :, :, None] * S0 + jnp.einsum(
        "bjhn,bjhm->bhnm", k_hat, vf)
    return y, S1


def tmix_seq(p, x, cfg: ModelConfig, shift_in=None, state_in=None,
             unroll=False):
    """x: (B,S,d). Returns (y, last_x, state_out)."""
    B, S, d = x.shape
    H, N = cfg.rwkv_heads, cfg.rwkv_head_size
    dt = cdtype(cfg)
    if shift_in is None:
        shift_in = jnp.zeros((B, d), dt)
    if state_in is None:
        state_in = jnp.zeros((B, H, N, N), jnp.float32)
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw = _rkvwg(p, x, x_prev, cfg)
    u = _heads(p["w_u"].astype(jnp.float32), H, N)

    c = min(cfg.rwkv_chunk, S)
    while S % c:
        c -= 1
    nc = S // c

    def to_chunks(t):
        return t.reshape(B, nc, c, H, N).transpose(1, 0, 2, 3, 4)
    rc, kc, vc = (to_chunks(_heads(t, H, N)) for t in (r, k, v))
    wc = to_chunks(_heads(logw, H, N))

    cdt = jnp.dtype(cfg.rwkv_chunk_dtype)

    def body(S0, inp):
        ri, ki, vi, wi = inp
        y, S1 = _chunk_core(ri, ki, vi, wi, u, S0, chunk_dtype=cdt)
        return S1, y
    if not unroll:
        body = jax.checkpoint(body)
    state_out, yc = jax.lax.scan(body, state_in, (rc, kc, vc, wc),
                                 unroll=(nc if unroll else 1))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    y = _group_norm(p, y, H, N).astype(dt)
    y = y * jax.nn.silu(g)
    y = jnp.einsum("...d,de->...e", y, p["w_o"].astype(dt))
    return shard(y, "B", None, None), x[:, -1], state_out


def tmix_ref(p, x, cfg: ModelConfig, shift_in=None, state_in=None):
    """Naive per-token recurrence — oracle for the chunked path."""
    B, S, d = x.shape
    H, N = cfg.rwkv_heads, cfg.rwkv_head_size
    dt = cdtype(cfg)
    if shift_in is None:
        shift_in = jnp.zeros((B, d), dt)
    if state_in is None:
        state_in = jnp.zeros((B, H, N, N), jnp.float32)
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw = _rkvwg(p, x, x_prev, cfg)
    u = _heads(p["w_u"].astype(jnp.float32), H, N)
    rs, ks, vs = (_heads(t, H, N).astype(jnp.float32) for t in (r, k, v))
    ws = jnp.exp(_heads(logw, H, N))

    def step(S0, inp):
        rt, kt, vt, wt = inp                              # (B,H,N)
        y = (jnp.einsum("bhn,bhnm->bhm", rt, S0)
             + jnp.einsum("bhn,bhn->bh", rt, u[None] * kt)[..., None] * vt)
        S1 = wt[..., None] * S0 + kt[..., None] * vt[..., None, :]
        return S1, y
    swap = lambda t: t.transpose(1, 0, 2, 3)
    state, ys = jax.lax.scan(step, state_in,
                             (swap(rs), swap(ks), swap(vs), swap(ws)))
    y = ys.transpose(1, 0, 2, 3)
    y = _group_norm(p, y, H, N).astype(dt)
    y = y * jax.nn.silu(g)
    y = jnp.einsum("...d,de->...e", y, p["w_o"].astype(dt))
    return y, x[:, -1], state


def tmix_decode(p, x1, cfg: ModelConfig, shift_in, state_in):
    """x1: (B,1,d); single-token recurrence."""
    B, _, d = x1.shape
    H, N = cfg.rwkv_heads, cfg.rwkv_head_size
    dt = cdtype(cfg)
    x_prev = shift_in[:, None]
    r, k, v, g, logw = _rkvwg(p, x1, x_prev, cfg)
    u = _heads(p["w_u"].astype(jnp.float32), H, N)
    rt, kt, vt = (_heads(t[:, 0], H, N).astype(jnp.float32) for t in (r, k, v))
    wt = jnp.exp(_heads(logw[:, 0], H, N))
    y = (jnp.einsum("bhn,bhnm->bhm", rt, state_in)
         + jnp.einsum("bhn,bhn->bh", rt, u[None] * kt)[..., None] * vt)
    S1 = wt[..., None] * state_in + kt[..., None] * vt[..., None, :]
    y = _group_norm(p, y[:, None], H, N).astype(dt)
    y = y * jax.nn.silu(g)
    y = jnp.einsum("...d,de->...e", y, p["w_o"].astype(dt))
    return shard(y, "B", None, None), x1[:, -1], S1


# ---------------------------------------------------------------------------


def cmix_seq(p, x, cfg: ModelConfig, shift_in=None, neuron_mask=None):
    B, S, d = x.shape
    dt = cdtype(cfg)
    if shift_in is None:
        shift_in = jnp.zeros((B, d), dt)
    x_prev = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["mix_k"].astype(dt)
    xr = x + xx * p["mix_r"].astype(dt)
    h = jnp.square(jax.nn.relu(
        jnp.einsum("...d,df->...f", xk, p["w_in"].astype(dt))))
    h = shard(h, "B", None, "M")
    if neuron_mask is not None:
        h = h * neuron_mask.astype(dt)
    kv = jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt))
    rgate = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["w_r"].astype(dt)))
    return shard(rgate * kv, "B", None, None), x[:, -1]


def cmix_decode(p, x1, cfg: ModelConfig, shift_in, neuron_mask=None):
    y, last = cmix_seq(p, x1, cfg, shift_in=shift_in, neuron_mask=neuron_mask)
    return y, x1[:, -1]
