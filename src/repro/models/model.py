"""Unified model API over all assigned architectures.

  init_params(cfg, key)                  -> params pytree
  forward_seq(params, cfg, batch, ...)   -> (logits, caches, aux)
  loss_fn(params, cfg, batch, ...)       -> (loss, metrics)
  decode_step(params, cfg, caches, ...)  -> (logits, new_caches)
  cache_specs(cfg, batch, seq_len, ...)  -> pytree of ShapeDtypeStruct
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard
from repro.models import encdec, transformer
from repro.models.layers import (cdtype, embed_tokens, init_embed, init_norm,
                                 apply_norm, lm_logits, softmax_xent)

ENC_MEM_LEN = 4096      # encoder memory length used by decode-shape caches


def init_params(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"tok": init_embed(k1, cfg), "final_norm": init_norm(cfg)}
    if cfg.is_encdec:
        p["stack"] = encdec.init_encdec_stack(k2, cfg)
        p["enc_norm"] = init_norm(cfg)
    else:
        seg_params, _ = transformer.init_stack(k2, cfg)
        p["stack"] = {f"seg{i}": sp for i, sp in enumerate(seg_params)}
    return p


def _seg_list(params, cfg):
    segs = transformer.build_segments(cfg)
    return [params["stack"][f"seg{i}"] for i in range(len(segs))], segs


def forward_seq(params, cfg: ModelConfig, batch, masks=None,
                window_override=None, unroll=False, want_cache=False,
                cache_len=None):
    """batch: {'tokens': (B,S) i32, optional 'frames': (B,M,d)}.
    Returns (logits, caches, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(params["tok"], tokens, cfg)

    if cfg.is_encdec:
        mem = encdec.run_encoder(params["stack"], batch["frames"], cfg,
                                 masks=masks["enc"] if masks else None,
                                 unroll=unroll)
        mem = apply_norm(params["enc_norm"], mem, cfg)
        x, caches = encdec.run_decoder_seq(
            params["stack"], x, mem, cfg, positions,
            masks=masks["dec"] if masks else None,
            window_override=window_override, unroll=unroll,
            want_cache=want_cache, cache_len=cache_len)
        aux = jnp.zeros((), jnp.float32)
        caches = [caches]
    else:
        seg_params, segs = _seg_list(params, cfg)
        x, caches, aux = transformer.run_stack_seq(
            seg_params, segs, x, cfg, positions, masks=masks,
            window_override=window_override, unroll=unroll,
            want_cache=want_cache, cache_len=cache_len)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["tok"], x, cfg)
    return logits, (caches if want_cache else None), aux


def loss_fn(params, cfg: ModelConfig, batch, masks=None,
            window_override=None, unroll=False):
    logits, _, aux = forward_seq(params, cfg, batch, masks=masks,
                                 window_override=window_override,
                                 unroll=unroll)
    mask = batch.get("loss_mask")
    xent = softmax_xent(logits, batch["targets"], mask)
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "aux": aux}


def decode_step(params, cfg: ModelConfig, caches, token, pos, masks=None,
                window_override=None, mla_absorb=False):
    """token: (B,1) i32; pos: (B,) i32. Returns (logits, new_caches)."""
    x = embed_tokens(params["tok"], token, cfg)
    if cfg.is_encdec:
        x, nc = encdec.run_decoder_decode(
            params["stack"], caches[0], x, cfg, pos,
            masks=masks["dec"] if masks else None,
            window_override=window_override)
        new_caches = [nc]
    else:
        seg_params, segs = _seg_list(params, cfg)
        x, new_caches = transformer.run_stack_decode(
            seg_params, segs, caches, x, cfg, pos, masks=masks,
            window_override=window_override, mla_absorb=mla_absorb)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["tok"], x, cfg)
    return logits, new_caches


def cache_specs(cfg: ModelConfig, batch, seq_len, window_override=None):
    if cfg.is_encdec:
        return [encdec.dec_cache_specs(cfg, batch, seq_len, ENC_MEM_LEN,
                                       window_override)]
    return transformer.stack_cache_specs(cfg, batch, seq_len,
                                         window_override)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_specs(cfg: ModelConfig, key=None):
    """ShapeDtypeStruct pytree of the params (no allocation)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda k: init_params(cfg, k), key)
