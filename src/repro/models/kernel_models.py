"""Fleet models whose masked matmuls can route through the Pallas kernels.

The paper-scale models in models/small.py express sub-models as dense
`mask * params` trees (DESIGN.md §8) — dense FLOPs at every dropout rate.
These two architectures keep the same contract (init / apply / UNIT_SPECS)
and add the kernel-side dual:

  apply_kernels(params, x, kmasks, interpret) -> logits
      identical math to `apply` on mask-consistent params, but the masked
      matmuls run through kernels/masked_ffn.py and kernels/masked_attn.py
      so dropped 128-blocks / heads are *skipped*, forward and backward
      (DESIGN.md §10) — a rate-r straggler actually does ~r of the FLOPs.
  kernel_masks(mask_tree) -> {"group": small mask}
      projects a dense keep-mask tree (core/submodel.keep_mask) down to the
      compact per-neuron / per-head vectors the kernels consume.

Equivalence contract (tests/test_kernel_grad.py): on params already masked
by `apply_mask`, `apply_kernels` == `apply` exactly (the hidden activations
the kernels skip are act(0) = 0), and `jax.grad` through either path gives
the same mask-projected update.

Kernel alignment drives the shapes: FFN hidden dims are multiples of
BLOCK_NEURONS=128, attention uses the decode_gqa head layout (heads
contiguous, head-dim fastest — the unit-major `tile < 0` grammar in
core/submodel.expand_indices).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.masked_attn import masked_attention
from repro.kernels.masked_ffn import masked_ffn_batch


def _dense(key, fan_in, shape):
    return jax.random.normal(key, shape) * (1.0 / math.sqrt(fan_in))


def _flat(x):
    return x.reshape(x.shape[0], -1)


class KernelMLP:
    """Flatten -> encode(64) -> masked FFN 64->1024->64 -> linear head.

    The FFN hidden layer (1024 = 8 x 128 blocks, gelu, no biases) is the
    droppable group; encoder and head are transferred whole. Sized for the
    FEMNIST stand-in (28x28x1, 62 classes)."""
    num_classes = 62
    input_shape = (28, 28, 1)
    d = 64
    hidden = 1024

    UNIT_SPECS = [
        {"name": "ffn", "size": 1024,
         "out": [("ffn/w_in", 1, 1)],
         "in": [("ffn/w_out", 0, 1)]},
    ]

    @staticmethod
    def init(key):
        ks = jax.random.split(key, 4)
        d, F = KernelMLP.d, KernelMLP.hidden
        return {
            "enc": _dense(ks[0], 784, (784, d)),
            "ffn": {"w_in": _dense(ks[1], d, (d, F)),
                    "w_out": _dense(ks[2], F, (F, d))},
            "out": {"w": _dense(ks[3], d, (d, 62)),
                    "b": jnp.zeros((62,), jnp.float32)},
        }

    @staticmethod
    def apply(params, x):
        z = _flat(x) @ params["enc"]
        h = jax.nn.gelu(z @ params["ffn"]["w_in"]) @ params["ffn"]["w_out"]
        return h @ params["out"]["w"] + params["out"]["b"]

    @staticmethod
    def kernel_masks(mask_tree):
        """Dense keep-mask tree -> per-neuron (1024,) 0/1 vector (a w_in
        column is 1 iff its neuron is kept)."""
        return {"ffn": mask_tree["ffn"]["w_in"].max(axis=0)}

    @staticmethod
    def apply_kernels(params, x, kmasks, interpret=True):
        z = _flat(x) @ params["enc"]
        rm = jnp.broadcast_to(kmasks["ffn"][None, :],
                              (z.shape[0], kmasks["ffn"].shape[0]))
        h = masked_ffn_batch(z, params["ffn"]["w_in"],
                             params["ffn"]["w_out"], rm, act="gelu",
                             interpret=interpret)
        return h @ params["out"]["w"] + params["out"]["b"]


class KernelAttnClassifier:
    """Patchify -> embed -> head-masked MHA -> block-masked FFN -> head.

    28x28 images become 49 patches of 16 pixels; one pre-norm-free
    transformer block with H=4 heads (hd=16, decode_gqa layout) and a
    64->256->64 gelu FFN (2 x 128 blocks), mean-pooled into a linear
    classifier. Two droppable groups: "heads" (unit-major tile = -16) and
    "ffn"."""
    num_classes = 62
    input_shape = (28, 28, 1)
    d = 64
    n_heads = 4
    head_dim = 16
    hidden = 256

    UNIT_SPECS = [
        {"name": "heads", "size": 4,
         "out": [("attn/wq", 1, -16), ("attn/wk", 1, -16),
                 ("attn/wv", 1, -16)],
         "in": [("attn/wo", 0, -16)]},
        {"name": "ffn", "size": 256,
         "out": [("ffn/w_in", 1, 1)],
         "in": [("ffn/w_out", 0, 1)]},
    ]

    @staticmethod
    def _patches(x):
        """(B, 28, 28, 1) -> (B, 49, 16): 7x7 grid of 4x4 patches."""
        B = x.shape[0]
        p = x.reshape(B, 7, 4, 7, 4).transpose(0, 1, 3, 2, 4)
        return p.reshape(B, 49, 16)

    @staticmethod
    def init(key):
        ks = jax.random.split(key, 8)
        d, F = KernelAttnClassifier.d, KernelAttnClassifier.hidden
        return {
            "embed": _dense(ks[0], 16, (16, d)),
            "attn": {"wq": _dense(ks[1], d, (d, d)),
                     "wk": _dense(ks[2], d, (d, d)),
                     "wv": _dense(ks[3], d, (d, d)),
                     "wo": _dense(ks[4], d, (d, d))},
            "ffn": {"w_in": _dense(ks[5], d, (d, F)),
                    "w_out": _dense(ks[6], F, (F, d))},
            "out": {"w": _dense(ks[7], d, (d, 62)),
                    "b": jnp.zeros((62,), jnp.float32)},
        }

    @staticmethod
    def _dense_attn(p, e):
        cls = KernelAttnClassifier
        B, S, d = e.shape
        H, hd = cls.n_heads, cls.head_dim
        x2 = e.reshape(B * S, d)
        q = (x2 @ p["wq"]).reshape(B, S, H, hd)
        k = (x2 @ p["wk"]).reshape(B, S, H, hd)
        v = (x2 @ p["wv"]).reshape(B, S, H, hd)
        s = jnp.einsum("bqhe,bkhe->bhqk", q, k) * (1.0 / math.sqrt(hd))
        causal = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(causal[None, None], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhqk,bkhe->bqhe", probs, v).reshape(B * S, H * hd)
        return (ctx @ p["wo"]).reshape(B, S, d)

    @staticmethod
    def apply(params, x):
        cls = KernelAttnClassifier
        e = cls._patches(x) @ params["embed"]
        h = e + cls._dense_attn(params["attn"], e)
        B, S, d = h.shape
        f = (jax.nn.gelu(h.reshape(B * S, d) @ params["ffn"]["w_in"])
             @ params["ffn"]["w_out"]).reshape(B, S, d)
        h = h + f
        pooled = h.mean(axis=1)
        return pooled @ params["out"]["w"] + params["out"]["b"]

    @staticmethod
    def kernel_masks(mask_tree):
        """Dense keep-mask tree -> {"heads": (4,), "ffn": (256,)} 0/1.
        A head is kept iff any of its wq columns is; unit-major layout
        (head-dim fastest), so columns group as (H, hd)."""
        cls = KernelAttnClassifier
        col = mask_tree["attn"]["wq"].max(axis=0)
        return {"heads": col.reshape(cls.n_heads, cls.head_dim).max(axis=1),
                "ffn": mask_tree["ffn"]["w_in"].max(axis=0)}

    @staticmethod
    def apply_kernels(params, x, kmasks, interpret=True):
        cls = KernelAttnClassifier
        e = cls._patches(x) @ params["embed"]
        a = masked_attention(e, params["attn"]["wq"], params["attn"]["wk"],
                             params["attn"]["wv"], params["attn"]["wo"],
                             kmasks["heads"], n_heads=cls.n_heads,
                             interpret=interpret)
        h = e + a
        B, S, d = h.shape
        rm = jnp.broadcast_to(kmasks["ffn"][None, :],
                              (B * S, kmasks["ffn"].shape[0]))
        f = masked_ffn_batch(h.reshape(B * S, d), params["ffn"]["w_in"],
                             params["ffn"]["w_out"], rm, act="gelu",
                             interpret=interpret).reshape(B, S, d)
        h = h + f
        pooled = h.mean(axis=1)
        return pooled @ params["out"]["w"] + params["out"]["b"]


KERNEL_MODELS = {"kernel_mlp": KernelMLP,
                 "kernel_attn": KernelAttnClassifier}
