"""Encoder–decoder stack (SeamlessM4T text/speech backbone).

Encoder: bidirectional attention layers over frontend frame embeddings (the
audio conv/mel frontend is a stub — inputs arrive as (B, S_enc, d) already).
Decoder: causal self-attention + cross-attention over encoder memory + FFN.
Both stacks are scanned (one segment each — uniform layers).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard
from repro.models import attention
from repro.models.layers import (apply_ffn, apply_norm, cdtype, init_ffn,
                                 init_norm)


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"norm1": init_norm(cfg),
            "attn": attention.init_attention(ks[0], cfg),
            "norm2": init_norm(cfg),
            "ffn": init_ffn(ks[1], cfg)}


def _init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg),
            "attn": attention.init_attention(ks[0], cfg),
            "norm_c": init_norm(cfg),
            "cross": attention.init_attention(ks[1], cfg),
            "norm2": init_norm(cfg),
            "ffn": init_ffn(ks[2], cfg)}


def init_encdec_stack(key, cfg: ModelConfig):
    ke, kd = jax.random.split(key)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {"enc": enc, "dec": dec}


def _cross_kv(p_cross, mem, cfg):
    dt = cdtype(cfg)
    k = jnp.einsum("bsd,dhk->bshk", mem, p_cross["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", mem, p_cross["wv"].astype(dt))
    if "bk" in p_cross:
        k, v = k + p_cross["bk"].astype(dt), v + p_cross["bv"].astype(dt)
    return k, v


def run_encoder(params, frames, cfg: ModelConfig, masks=None, unroll=False):
    """frames: (B,S,d). Bidirectional."""
    S = frames.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = shard(frames.astype(cdtype(cfg)), "B", None, None)

    def body(carry, xs):
        xc = carry
        p, m = xs
        h = apply_norm(p["norm1"], xc, cfg)
        y, _ = attention.attn_seq(p["attn"], h, cfg, positions,
                                  causal=False, unroll=unroll)
        xc = xc + y
        h2 = apply_norm(p["norm2"], xc, cfg)
        nm = m.get("ffn") if m is not None else None
        xc = xc + apply_ffn(p["ffn"], h2, cfg, neuron_mask=nm)
        return shard(xc, "B", "M", None), 0

    fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = jax.lax.scan(fn, x, (params["enc"], masks), length=cfg.enc_layers)
    return x


def _dec_layer_seq(p, x, mem_kv, cfg, positions, mask, window_override,
                   unroll, want_cache, cache_len=None):
    mem_k, mem_v = mem_kv
    win = window_override
    h = apply_norm(p["norm1"], x, cfg)
    y, (k, v) = attention.attn_seq(p["attn"], h, cfg, positions, window=win,
                                   unroll=unroll)
    cache = {}
    if want_cache:
        from repro.models.transformer import _ring_from_seq
        cache["attn"] = _ring_from_seq({"k": k, "v": v}, positions, win, cfg,
                                       cache_len)
        cache["cross_k"], cache["cross_v"] = mem_k, mem_v
    x = x + y
    hc = apply_norm(p["norm_c"], x, cfg)
    mpos = jnp.zeros((mem_k.shape[1],), jnp.int32)
    y, _ = attention.attn_seq(p["cross"], hc, cfg, positions,
                              kv_override=(mem_k, mem_v), kv_positions=mpos,
                              unroll=unroll)
    x = x + y
    h2 = apply_norm(p["norm2"], x, cfg)
    nm = mask.get("ffn") if mask is not None else None
    x = x + apply_ffn(p["ffn"], h2, cfg, neuron_mask=nm)
    return x, cache


def run_decoder_seq(params, x, memory, cfg: ModelConfig, positions,
                    masks=None, window_override=None, unroll=False,
                    want_cache=False, cache_len=None):
    """x: (B,S,d) decoder token embeddings; memory: (B,M,d)."""
    def body(xc, xs):
        p, m = xs
        mem_kv = _cross_kv(p["cross"], memory, cfg)
        xc, cache = _dec_layer_seq(p, xc, mem_kv, cfg, positions, m,
                                   window_override, unroll, want_cache,
                                   cache_len)
        return shard(xc, "B", "M", None), (cache if want_cache else 0)

    fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, caches = jax.lax.scan(fn, x, (params["dec"], masks),
                             length=cfg.n_layers)
    return x, (caches if want_cache else None)


def run_decoder_decode(params, caches, x, cfg: ModelConfig, pos, masks=None,
                       window_override=None):
    """x: (B,1,d)."""
    def body(xc, xs):
        p, c, m = xs
        h = apply_norm(p["norm1"], xc, cfg)
        y, cc, slots = attention.attn_decode(
            p["attn"], h, cfg, {k: c["attn"][k] for k in ("k", "v")},
            c["attn"]["slots"], pos, window=window_override)
        cc["slots"] = slots
        xc = xc + y
        hc = apply_norm(p["norm_c"], xc, cfg)
        mpos = jnp.zeros((c["cross_k"].shape[1],), jnp.int32)
        y, _ = attention.attn_seq(p["cross"], hc, cfg, pos[:, None],
                                  kv_override=(c["cross_k"], c["cross_v"]),
                                  kv_positions=mpos)
        xc = xc + y
        h2 = apply_norm(p["norm2"], xc, cfg)
        nm = m.get("ffn") if m is not None else None
        xc = xc + apply_ffn(p["ffn"], h2, cfg, neuron_mask=nm)
        new_c = dict(c)
        new_c["attn"] = cc
        return xc, new_c

    x, nc = jax.lax.scan(body, x, (params["dec"], caches, masks),
                         length=cfg.n_layers)
    return x, nc


def dec_cache_specs(cfg: ModelConfig, batch, seq_len, mem_len,
                    window_override=None):
    C = seq_len if window_override is None else min(window_override, seq_len)
    dt = jnp.dtype(cfg.dtype)
    per = {"attn": dict(attention.cache_spec(cfg, batch, C),
                        slots=jax.ShapeDtypeStruct((batch, C), jnp.int32)),
           "cross_k": jax.ShapeDtypeStruct(
               (batch, mem_len, cfg.n_kv_heads, cfg.head_dim), dt),
           "cross_v": jax.ShapeDtypeStruct(
               (batch, mem_len, cfg.n_kv_heads, cfg.head_dim), dt)}
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        per)
