"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: x -> [W_x -> causal depthwise conv1d -> RG-LRU] ⊙ gelu(W_gate x) -> W_out.
RG-LRU:
  r_t = sigmoid(w_a ⊙ x_t + b_a)        (recurrence gate, per-channel)
  i_t = sigmoid(w_i ⊙ x_t + b_i)        (input gate)
  a_t = exp(-c * softplus(lam) * r_t)   (c = 8)
  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

Training/prefill evaluate the linear recurrence with a log-depth
``jax.lax.associative_scan`` (TPU-friendly: no sequential loop); decode is the
one-step recurrence. Gates are per-channel (the published model uses
block-diagonal head gates; the diagonal special case keeps the parameter
budget faithful — noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard
from repro.models.layers import cdtype, dense_init, pdtype

C_FACTOR = 8.0


def init_rglru(key, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_dim
    ks = jax.random.split(key, 5)
    pd = pdtype(cfg)
    return {
        "w_x": dense_init(ks[0], d, d, w, dtype=pd),
        "w_gate": dense_init(ks[1], d, d, w, dtype=pd),
        "w_out": dense_init(ks[2], w, w, d, dtype=pd),
        "conv_w": 0.1 * jax.random.normal(ks[3], (cfg.conv1d_width, w), pd),
        "conv_b": jnp.zeros((w,), pd),
        "a_param": jnp.linspace(0.9, 4.0, w, dtype=pd),  # softplus arg
        "w_a": 0.1 * jax.random.normal(ks[4], (w,), pd),
        "b_a": jnp.zeros((w,), pd),
        "w_i": 0.1 * jax.random.normal(jax.random.fold_in(ks[4], 1), (w,), pd),
        "b_i": jnp.zeros((w,), pd),
    }


def _conv1d_seq(p, u, conv_state, cfg):
    """Causal depthwise conv. u: (B,S,w); conv_state: (B, K-1, w) history."""
    K = cfg.conv1d_width
    dt = u.dtype
    hist = jnp.concatenate([conv_state.astype(dt), u], axis=1)  # (B, S+K-1, w)
    out = jnp.zeros_like(u)
    S = u.shape[1]
    for j in range(K):
        out = out + hist[:, j:j + S] * p["conv_w"][K - 1 - j].astype(dt)
    out = out + p["conv_b"].astype(dt)
    new_state = hist[:, -(K - 1):]
    return out, new_state


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_a"].astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["w_i"].astype(jnp.float32)
                       + p["b_i"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(
        p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_seq(p, x, cfg: ModelConfig, state_in=None, conv_in=None):
    """x: (B,S,d). Returns (y, {'h','conv'} state)."""
    B, S, _ = x.shape
    w = cfg.lru_dim
    dt = cdtype(cfg)
    if state_in is None:
        state_in = jnp.zeros((B, w), jnp.float32)
    if conv_in is None:
        conv_in = jnp.zeros((B, cfg.conv1d_width - 1, w), dt)

    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(dt))
    u = shard(u, "B", None, "M")
    u, conv_out = _conv1d_seq(p, u, conv_in, cfg)
    a, b = _gates(p, u)

    # prepend carried state as a pseudo-step: h_0 absorbed via (a=1,b=state)
    a_full = jnp.concatenate([jnp.ones((B, 1, w), jnp.float32), a], axis=1)
    b_full = jnp.concatenate([state_in[:, None], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    aa, hh = jax.lax.associative_scan(combine, (a_full, b_full), axis=1)
    h = hh[:, 1:]                                         # (B,S,w)
    state_out = hh[:, -1]

    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt)))
    y = (h.astype(dt) * gate)
    y = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dt))
    return shard(y, "B", None, None), {"h": state_out, "conv": conv_out}


def rglru_decode(p, x1, cfg: ModelConfig, state):
    """x1: (B,1,d); state: {'h': (B,w) fp32, 'conv': (B,K-1,w)}."""
    dt = cdtype(cfg)
    u = jnp.einsum("bsd,dw->bsw", x1, p["w_x"].astype(dt))
    K = cfg.conv1d_width
    hist = jnp.concatenate([state["conv"].astype(dt), u], axis=1)  # (B,K,w)
    # seq path: conv_w[0] multiplies the newest step -> flip for the history
    conv = jnp.einsum("bkw,kw->bw", hist,
                      p["conv_w"][::-1].astype(dt))[:, None]
    conv = conv + p["conv_b"].astype(dt)
    a, b = _gates(p, conv)
    h = a[:, 0] * state["h"] + b[:, 0]
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x1, p["w_gate"].astype(dt)))
    y = (h[:, None].astype(dt) * gate)
    y = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dt))
    return shard(y, "B", None, None), {"h": h, "conv": hist[:, 1:]}


def state_spec(cfg: ModelConfig, batch: int):
    return {"h": jax.ShapeDtypeStruct((batch, cfg.lru_dim), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.conv1d_width - 1, cfg.lru_dim),
                jnp.dtype(cfg.dtype))}
