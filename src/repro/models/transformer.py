"""Segment-based decoder stack.

A model is a list of *segments*: (unit_pattern, repeats). A unit is a short
tuple of LayerSpecs (mixer kind, ffn kind); params for a segment are stacked
over repeats and the segment is evaluated with ``jax.lax.scan`` so HLO size is
O(#segments), not O(depth). Mixed-pattern archs (RecurrentGemma 2:1,
DeepSeek first-dense-layer) decompose into a few segments.

Layer kinds:  attn | local_attn | rglru | rwkv    (mixer)
              dense | moe | cmix                  (ffn)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard
from repro.models import attention, mla, moe, rglru, rwkv6
from repro.models.layers import (apply_ffn, apply_norm, cdtype, init_ffn,
                                 init_norm)

LayerSpec = Tuple[str, str]        # (mixer, ffn)


@dataclass(frozen=True)
class Segment:
    unit: Tuple[LayerSpec, ...]
    repeats: int


def layer_specs(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    out = []
    for i in range(cfg.n_layers):
        mixer = cfg.block_pattern[i % len(cfg.block_pattern)]
        if mixer == "rwkv":
            ffn = "cmix"
        else:
            ffn = cfg.ffn_kind_for_layer(i)
        out.append((mixer, ffn))
    return tuple(out)


def _rle(specs):
    runs = []
    for s in specs:
        if runs and runs[-1][0] == s:
            runs[-1][1] += 1
        else:
            runs.append([s, 1])
    return runs


def build_segments(cfg: ModelConfig) -> Tuple[Segment, ...]:
    specs = layer_specs(cfg)
    runs = _rle(specs)
    if len(runs) <= 3:
        return tuple(Segment((s,), n) for s, n in runs)
    unit = specs[:len(cfg.block_pattern)]
    k = len(specs) // len(unit)
    rem = specs[k * len(unit):]
    segs = [Segment(unit, k)]
    segs += [Segment((s,), n) for s, n in _rle(rem)]
    return tuple(segs)


# ---------------------------------------------------------------------------
# init

def _init_layer(key, spec: LayerSpec, cfg: ModelConfig):
    mixer, ffn = spec
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg)}
    if mixer in ("attn", "local_attn"):
        p["attn" if not cfg.use_mla else "mla"] = (
            attention.init_attention(ks[0], cfg) if not cfg.use_mla
            else mla.init_mla(ks[0], cfg))
    elif mixer == "rglru":
        p["rglru"] = rglru.init_rglru(ks[0], cfg)
    elif mixer == "rwkv":
        p["rwkv"] = rwkv6.init_tmix(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if not cfg.parallel_block:
        p["norm2"] = init_norm(cfg)
    if ffn == "dense":
        p["ffn"] = init_ffn(ks[1], cfg)
    elif ffn == "moe":
        p["moe"] = moe.init_moe(ks[1], cfg)
    elif ffn == "cmix":
        p["cmix"] = rwkv6.init_cmix(ks[1], cfg)
    else:
        raise ValueError(ffn)
    return p


def init_segment(key, seg: Segment, cfg: ModelConfig):
    def init_unit(k):
        kk = jax.random.split(k, len(seg.unit))
        return {f"l{i}": _init_layer(kk[i], s, cfg)
                for i, s in enumerate(seg.unit)}
    keys = jax.random.split(key, seg.repeats)
    return jax.vmap(init_unit)(keys)


def init_stack(key, cfg: ModelConfig):
    segs = build_segments(cfg)
    keys = jax.random.split(key, len(segs))
    return [init_segment(k, s, cfg) for k, s in zip(keys, segs)], segs


# ---------------------------------------------------------------------------
# sequence (train / prefill) pass

def _layer_window(mixer: str, cfg: ModelConfig, window_override):
    if mixer == "local_attn":
        return cfg.window
    if window_override is not None:         # long-context windowed variant
        return window_override
    return None


def _apply_layer_seq(spec, p, x, cfg: ModelConfig, positions, masks,
                     window_override, unroll, want_cache, cache_len=None):
    """Returns (x, cache_entry, aux)."""
    mixer, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    h = apply_norm(p["norm1"], x, cfg)
    if mixer in ("attn", "local_attn"):
        win = _layer_window(mixer, cfg, window_override)
        if cfg.use_mla:
            y, (c_kv, k_rope) = mla.mla_seq(p["mla"], h, cfg, positions,
                                            unroll=unroll)
            if want_cache:
                cache["mla"] = _ring_from_seq(
                    {"c_kv": c_kv, "k_rope": k_rope}, positions, win, cfg,
                    cache_len)
        else:
            y, (k, v) = attention.attn_seq(p["attn"], h, cfg, positions,
                                           window=win, unroll=unroll)
            if want_cache:
                cache["attn"] = _ring_from_seq({"k": k, "v": v}, positions,
                                               win, cfg, cache_len)
        mix_out = y
        shift_cm = None
    elif mixer == "rglru":
        y, st = rglru.rglru_seq(p["rglru"], h, cfg)
        if want_cache:
            cache["rglru"] = st
        mix_out = y
        shift_cm = None
    elif mixer == "rwkv":
        y, last_x, state = rwkv6.tmix_seq(p["rwkv"], h, cfg, unroll=unroll)
        if want_cache:
            cache["rwkv"] = {"S": state, "shift_tm": last_x}
        mix_out = y
        shift_cm = True
    else:
        raise ValueError(mixer)

    if cfg.parallel_block:
        f = apply_ffn(p["ffn"], h, cfg, neuron_mask=_m(masks, "ffn"))
        return x + mix_out + f, cache, aux

    x = x + mix_out
    h2 = apply_norm(p["norm2"], x, cfg)
    if ffn == "dense":
        x = x + apply_ffn(p["ffn"], h2, cfg, neuron_mask=_m(masks, "ffn"))
    elif ffn == "moe":
        y, aux = moe.apply_moe(p["moe"], h2, cfg,
                               neuron_mask=_m(masks, "moe"),
                               expert_mask=_m(masks, "experts"))
        x = x + y
    elif ffn == "cmix":
        y, last_cm = rwkv6.cmix_seq(p["cmix"], h2, cfg,
                                    neuron_mask=_m(masks, "ffn"))
        if want_cache and "rwkv" in cache:
            cache["rwkv"]["shift_cm"] = last_cm
        x = x + y
    return x, cache, aux


def _m(masks, key):
    if masks is None:
        return None
    return masks.get(key)


def _ring_from_seq(tensors, positions, window, cfg, cache_len=None):
    """Fold full-sequence K/V (B,S,...) into a ring cache of length C.
    cache_len > S leaves decode headroom (prefill-then-generate)."""
    S = positions.shape[-1]
    cap = cache_len or S
    C = cap if window is None else min(window, cap)
    out = {}
    for name, t in tensors.items():
        if C == S:
            ring = t
            slots = jnp.broadcast_to(positions, (t.shape[0], S)).astype(jnp.int32)
        else:
            # last min(C,S) positions land at slot pos % C
            n = min(C, S)
            tail = t[:, -n:]
            ptail = positions[-n:]
            idx = (ptail % C).astype(jnp.int32)
            ring = jnp.zeros((t.shape[0], C) + t.shape[2:], t.dtype)
            ring = ring.at[:, idx].set(tail)
            slots = jnp.full((t.shape[0], C), -1, jnp.int32).at[:, idx].set(
                ptail.astype(jnp.int32))
        out[name] = ring
    out["slots"] = slots
    return out


def run_stack_seq(seg_params, segs, x, cfg: ModelConfig, positions,
                  masks=None, window_override=None, unroll=False,
                  want_cache=False, cache_len=None):
    """x: (B,S,d). Returns (x, caches, aux_sum). masks: list per segment of
    per-unit dicts with stacked (R, ...) leaves, or None."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for si, (seg, sp) in enumerate(zip(segs, seg_params)):
        smasks = masks[si] if masks is not None else None

        def unit_body(carry, xs):
            xc, auxc = carry
            up, um = xs
            cache_u = {}
            for i, spec in enumerate(seg.unit):
                lm = um[f"l{i}"] if um is not None else None
                xc, ce, aux = _apply_layer_seq(
                    spec, up[f"l{i}"], xc, cfg, positions, lm,
                    window_override, unroll, want_cache, cache_len)
                cache_u[f"l{i}"] = ce
                auxc = auxc + aux
            # sequence-sharded residual carry: the activation stored per layer
            # for the remat backward is 1/|model| of the full stream
            xc = shard(xc, "B", "M", None)
            return (xc, auxc), (cache_u if want_cache else 0)

        body = unit_body
        if cfg.remat == "block":
            body = jax.checkpoint(unit_body)
        (x, aux_total), ys = jax.lax.scan(
            body, (x, aux_total), (sp, smasks), length=seg.repeats)
        caches.append(ys if want_cache else None)
    return x, caches, aux_total


# ---------------------------------------------------------------------------
# decode pass

def _apply_layer_decode(spec, p, x, cache, cfg: ModelConfig, pos, masks,
                        window_override, mla_absorb=False):
    mixer, ffn = spec
    h = apply_norm(p["norm1"], x, cfg)
    new_cache = dict(cache)
    if mixer in ("attn", "local_attn"):
        win = _layer_window(mixer, cfg, window_override)
        if cfg.use_mla:
            c = cache["mla"]
            y, cc, slots = mla.mla_decode(p["mla"], h, cfg,
                                          {k: c[k] for k in ("c_kv", "k_rope")},
                                          c["slots"], pos,
                                          absorb=mla_absorb)
            cc["slots"] = slots
            new_cache["mla"] = cc
        else:
            c = cache["attn"]
            y, cc, slots = attention.attn_decode(
                p["attn"], h, cfg, {k: c[k] for k in ("k", "v")},
                c["slots"], pos, window=win)
            cc["slots"] = slots
            new_cache["attn"] = cc
    elif mixer == "rglru":
        y, st = rglru.rglru_decode(p["rglru"], h, cfg, cache["rglru"])
        new_cache["rglru"] = st
    elif mixer == "rwkv":
        c = cache["rwkv"]
        y, last_x, S1 = rwkv6.tmix_decode(p["rwkv"], h, cfg,
                                          c["shift_tm"], c["S"])
        new_cache["rwkv"] = {"S": S1, "shift_tm": last_x,
                             "shift_cm": c["shift_cm"]}
    else:
        raise ValueError(mixer)

    if cfg.parallel_block:
        f = apply_ffn(p["ffn"], h, cfg, neuron_mask=_m(masks, "ffn"))
        return x + y + f, new_cache

    x = x + y
    h2 = apply_norm(p["norm2"], x, cfg)
    if ffn == "dense":
        x = x + apply_ffn(p["ffn"], h2, cfg, neuron_mask=_m(masks, "ffn"))
    elif ffn == "moe":
        ym, _ = moe.apply_moe(p["moe"], h2, cfg,
                              neuron_mask=_m(masks, "moe"),
                              expert_mask=_m(masks, "experts"))
        x = x + ym
    elif ffn == "cmix":
        ym, last_cm = rwkv6.cmix_decode(p["cmix"], h2, cfg,
                                        cache["rwkv"]["shift_cm"],
                                        neuron_mask=_m(masks, "ffn"))
        new_cache["rwkv"]["shift_cm"] = last_cm
        x = x + ym
    return x, new_cache


def run_stack_decode(seg_params, segs, caches, x, cfg: ModelConfig, pos,
                     masks=None, window_override=None, mla_absorb=False):
    """x: (B,1,d). Returns (x, new_caches)."""
    new_caches = []
    for si, (seg, sp) in enumerate(zip(segs, seg_params)):
        smasks = masks[si] if masks is not None else None

        def unit_body(xc, xs):
            up, uc, um = xs
            new_u = {}
            for i, spec in enumerate(seg.unit):
                lm = um[f"l{i}"] if um is not None else None
                xc, nc = _apply_layer_decode(spec, up[f"l{i}"], xc,
                                             uc[f"l{i}"], cfg, pos, lm,
                                             window_override, mla_absorb)
                new_u[f"l{i}"] = nc
            return xc, new_u

        x, nc = jax.lax.scan(unit_body, x, (sp, caches[si], smasks),
                             length=seg.repeats)
        new_caches.append(nc)
    return x, new_caches


# ---------------------------------------------------------------------------
# cache specs

def _layer_cache_spec(spec, cfg: ModelConfig, batch, seq_len, window_override):
    mixer, ffn = spec
    out = {}
    if mixer in ("attn", "local_attn"):
        win = _layer_window(mixer, cfg, window_override)
        C = seq_len if win is None else min(win, seq_len)
        if cfg.use_mla:
            d = mla.cache_spec(cfg, batch, C)
            d["slots"] = jax.ShapeDtypeStruct((batch, C), jnp.int32)
            out["mla"] = d
        else:
            d = attention.cache_spec(cfg, batch, C)
            d["slots"] = jax.ShapeDtypeStruct((batch, C), jnp.int32)
            out["attn"] = d
    elif mixer == "rglru":
        out["rglru"] = rglru.state_spec(cfg, batch)
    elif mixer == "rwkv":
        H, N = cfg.rwkv_heads, cfg.rwkv_head_size
        out["rwkv"] = {
            "S": jax.ShapeDtypeStruct((batch, H, N, N), jnp.float32),
            "shift_tm": jax.ShapeDtypeStruct((batch, cfg.d_model),
                                             jnp.dtype(cfg.dtype)),
            "shift_cm": jax.ShapeDtypeStruct((batch, cfg.d_model),
                                             jnp.dtype(cfg.dtype))}
    return out


def stack_cache_specs(cfg: ModelConfig, batch, seq_len, window_override=None):
    segs = build_segments(cfg)
    out = []
    for seg in segs:
        unit = {f"l{i}": _layer_cache_spec(s, cfg, batch, seq_len,
                                           window_override)
                for i, s in enumerate(seg.unit)}
        out.append(jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((seg.repeats,) + s.shape, s.dtype),
            unit))
    return out
