"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

KV is compressed to a latent c_kv of rank ``kv_lora_rank`` plus a shared
rope-carrying key slice. The decode cache stores only (c_kv, k_rope).

Two decode paths:
  * baseline  -- expand K/V from the latent for every cached slot (faithful
                 to the reference formulation)
  * absorbed  -- absorb W_uk / W_uv into the query/output projections and
                 attend directly in latent space (beyond-paper perf path;
                 cuts decode memory traffic by ~H*(nope+v)/lora)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard
from repro.models.attention import NEG
from repro.models.layers import apply_rope, cdtype, dense_init, pdtype


def init_mla(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim,
                            cfg.v_head_dim, cfg.kv_lora_rank)
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], d, d, cfg.q_lora_rank, dtype=pdtype(cfg))
        p["w_uq"] = dense_init(ks[1], cfg.q_lora_rank, cfg.q_lora_rank, H,
                               nope + rope, dtype=pdtype(cfg))
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), pdtype(cfg))
    else:
        p["wq"] = dense_init(ks[0], d, d, H, nope + rope, dtype=pdtype(cfg))
    p["w_dkv"] = dense_init(ks[2], d, d, lora + rope, dtype=pdtype(cfg))
    p["kv_norm"] = jnp.ones((lora,), pdtype(cfg))
    p["w_uk"] = dense_init(ks[3], lora, lora, H, nope, dtype=pdtype(cfg))
    p["w_uv"] = dense_init(ks[4], lora, lora, H, vd, dtype=pdtype(cfg))
    p["wo"] = dense_init(ks[5], H * vd, H, vd, d, dtype=pdtype(cfg))
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _queries(p, x, cfg: ModelConfig, positions):
    dt = cdtype(cfg)
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = _rms(jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(dt)),
                  p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return shard(q_nope, "B", None, "M", None), shard(q_rope, "B", None, "M", None)


def _latent(p, x, cfg: ModelConfig, positions):
    dt = cdtype(cfg)
    lora = cfg.kv_lora_rank
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt))
    c_kv = _rms(ckv_full[..., :lora], p["kv_norm"])
    k_rope = apply_rope(ckv_full[..., lora:], positions, cfg.rope_theta, has_heads=False)
    return c_kv, k_rope


def _attend(p, q_nope, q_rope, c_kv, k_rope, cfg, q_pos, kv_pos):
    """Baseline attention: expand k,v from latent. Shapes:
    q_*: (B,Sq,H,·)  c_kv: (B,T,lora)  k_rope: (B,T,rope)."""
    dt = cdtype(cfg)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"].astype(dt))
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"].astype(dt))
    s = (jnp.einsum("bqhk,bthk->bhqt", q_nope, k_nope)
         + jnp.einsum("bqhk,btk->bhqt", q_rope, k_rope))
    s = s.astype(jnp.float32) * scale
    qb = q_pos[:, None, :, None] if q_pos.ndim == 2 else q_pos[None, None, :, None]
    kb = kv_pos[:, None, None, :] if kv_pos.ndim == 2 else kv_pos[None, None, None, :]
    s = jnp.where((kb >= 0) & (kb <= qb), s, NEG)
    w = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqt,bthk->bqhk", w, v)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
    return shard(y, "B", None, None)


def mla_seq(p, x, cfg: ModelConfig, positions, unroll=False):
    """Train/prefill. Returns (y, (c_kv, k_rope)) for cache capture."""
    B, S, _ = x.shape
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latent(p, x, cfg, positions)
    CH = 1024
    if S <= CH:
        y = _attend(p, q_nope, q_rope, c_kv, k_rope, cfg, positions, positions)
    else:
        n = S // CH

        def body(_, qp):
            qn, qr, pi = qp
            return (), _attend(p, qn, qr, c_kv, k_rope, cfg, pi, positions)
        if not unroll:
            body = jax.checkpoint(body)
        qn = q_nope.reshape(B, n, CH, *q_nope.shape[2:]).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, n, CH, *q_rope.shape[2:]).transpose(1, 0, 2, 3, 4)
        pc = positions.reshape(n, CH)
        _, yc = jax.lax.scan(body, (), (qn, qr, pc), unroll=(n if unroll else 1))
        y = yc.transpose(1, 0, 2, 3).reshape(B, S, -1)
    return y, (c_kv, k_rope)


def mla_decode(p, x, cfg: ModelConfig, cache, slot_pos, pos, absorb=False):
    """cache: {'c_kv': (B,C,lora), 'k_rope': (B,C,rope)}."""
    dt = cdtype(cfg)
    C = cache["c_kv"].shape[1]
    q_nope, q_rope = _queries(p, x, cfg, pos[:, None])
    c_new, kr_new = _latent(p, x, cfg, pos[:, None])

    idx = (pos % C).astype(jnp.int32)
    upd = (jnp.arange(C, dtype=jnp.int32)[None, :] == idx[:, None])
    ckv = jnp.where(upd[:, :, None], c_new, cache["c_kv"])
    krope = jnp.where(upd[:, :, None], kr_new, cache["k_rope"])
    new_slots = jnp.where(upd, pos[:, None], slot_pos)

    if not absorb:
        y = _attend(p, q_nope, q_rope, ckv, krope, cfg, pos[:, None], new_slots)
    else:
        scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
        # absorb W_uk into q, attend in latent space, then W_uv on the output
        q_eff = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["w_uk"].astype(dt))
        s = (jnp.einsum("bqhr,btr->bhqt", q_eff, ckv)
             + jnp.einsum("bqhk,btk->bhqt", q_rope, krope))
        s = s.astype(jnp.float32) * scale
        kb = new_slots[:, None, None, :]
        s = jnp.where((kb >= 0) & (kb <= pos[:, None, None, None]), s, NEG)
        w = jax.nn.softmax(s, axis=-1).astype(dt)
        lat = jnp.einsum("bhqt,btr->bqhr", w, ckv)
        out = jnp.einsum("bqhr,rhk->bqhk", lat, p["w_uv"].astype(dt))
        y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
        y = shard(y, "B", None, None)
    return y, {"c_kv": ckv, "k_rope": krope}, new_slots


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.dtype)
    return {"c_kv": jax.ShapeDtypeStruct((batch, cache_len, cfg.kv_lora_rank), dt),
            "k_rope": jax.ShapeDtypeStruct((batch, cache_len, cfg.qk_rope_dim), dt)}
