"""Population layer: a device-resident client registry + the round driver.

FLuID's server decisions (straggler membership, dropout rate, sub-model
shape) are functions of per-client performance profiles. The paper's
evaluation holds ~5 real phones; the ROADMAP north-star is a production
service with 10^5-10^6 registered users of which a few hundred train per
round. At that scale per-round Python dicts are the wrong data structure —
the registry must live on device, in struct-of-arrays form, and be cheap to
sample from and scatter into.

`ClientStore` is that registry: one compact pytree of (N,) / (N, H) arrays
(speed EMA + ring-buffer history of observed full-model-equivalent
latencies, straggler-membership EMA, currently assigned dropout rate,
data-shard id, rounds participated, active flag, and the emulation's
ground-truth speed). All ops are pure functions returning a new store, so
they jit, and the store passes through `jax.jit` boundaries as an ordinary
pytree:

  * `register(slots, speeds, shards)`  — activate clients in bulk;
  * `sample_cohort(key, size)`         — deterministic seeded sampling
    without replacement (Gumbel top-k over active clients; fixed output
    shape, sorted ids) — the same key gives the same cohort on any device
    count, which the 1-vs-2-device bitwise test relies on;
  * `update_from_round(ids, lat, rates)` — scatter one round's observed
    latencies into the EMA/ring history and bump participation;
  * `assign_rates(ids, rates)`          — write the calibration plan's
    dropout rates back, so the *next* cohort containing those clients
    trains the right sub-model;
  * `set_speed(ids, speeds)`            — emulation ground truth, giving
    mid-run drift (paper Fig. 4b) a single source of truth.

`PopulationSim` is the round driver over the store: sample a cohort,
materialize its clients from the data-shard partitions, hand them to a
`RoundBackend` (fl/rounds.py: sequential / fleet / sharded_fleet), and let
`core/fluid.FluidServer` run the FLuID round against the store. Straggler
detection (core/straggler.plan_from_store) reads the store's speed history
instead of per-round dicts.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fluid import FluidConfig, FluidServer

_EMA = 0.25                      # weight of the newest observation
DEFAULT_HISTORY = 4              # latency ring-buffer depth per client


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class ClientStore:
    """Struct-of-arrays registry for the whole client population.

    All fields are arrays with leading dim N (the registry capacity); the
    store itself is a pytree, so it moves through jit/shard boundaries
    whole. Slots are client ids: slot i holds client i.
    """
    speed: jnp.ndarray                # (N,) f32 ground-truth s/epoch (emulation)
    speed_ema: jnp.ndarray            # (N,) f32 EMA of observed latencies
    speed_hist: jnp.ndarray           # (N, H) f32 latency ring buffer (NaN=empty)
    straggler_ema: jnp.ndarray        # (N,) f32 EMA of straggler membership
    dropout_rate: jnp.ndarray         # (N,) f32 assigned sub-model size (1=full)
    data_shard: jnp.ndarray           # (N,) i32 dataset partition id
    rounds_participated: jnp.ndarray  # (N,) i32
    active: jnp.ndarray               # (N,) bool registered & eligible
    in_flight: jnp.ndarray            # (N,) bool dispatched, not yet arrived

    # ------------------------------------------------------------ pytree
    _FIELDS = ("speed", "speed_ema", "speed_hist", "straggler_ema",
               "dropout_rate", "data_shard", "rounds_participated", "active",
               "in_flight")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ------------------------------------------------------------- shape
    @property
    def capacity(self) -> int:
        return self.active.shape[0]

    @property
    def history(self) -> int:
        return self.speed_hist.shape[1]

    @property
    def n_active(self) -> int:
        return int(jnp.sum(self.active))

    # ------------------------------------------------------ construction
    @classmethod
    def empty(cls, capacity: int, history: int = DEFAULT_HISTORY):
        return cls(
            speed=jnp.zeros((capacity,), jnp.float32),
            speed_ema=jnp.zeros((capacity,), jnp.float32),
            speed_hist=jnp.full((capacity, history), jnp.nan, jnp.float32),
            straggler_ema=jnp.zeros((capacity,), jnp.float32),
            dropout_rate=jnp.ones((capacity,), jnp.float32),
            data_shard=jnp.zeros((capacity,), jnp.int32),
            rounds_participated=jnp.zeros((capacity,), jnp.int32),
            active=jnp.zeros((capacity,), bool),
            in_flight=jnp.zeros((capacity,), bool),
        )

    def register(self, slots, speeds, data_shards) -> "ClientStore":
        """Activate `slots` with emulation speeds + data-shard assignment."""
        idx = jnp.asarray(slots, jnp.int32)
        return dataclasses.replace(
            self,
            speed=self.speed.at[idx].set(jnp.asarray(speeds, jnp.float32)),
            data_shard=self.data_shard.at[idx].set(
                jnp.asarray(data_shards, jnp.int32)),
            active=self.active.at[idx].set(True),
        )

    # --------------------------------------------------------------- ops
    def sample_cohort(self, key, size: int,
                      available_only: bool = False) -> jnp.ndarray:
        """Seeded without-replacement sample of `size` active clients.

        Gumbel top-k: score eligible clients by iid Gumbel noise and take
        the k best — a fixed-shape program whose result depends only on
        (eligibility, key), never on device layout. Ids come back sorted so
        downstream host loops are order-stable. `available_only=True`
        additionally excludes clients currently in flight (dispatched by
        the async backend, delta not yet arrived).

        Raises ValueError when fewer than `size` clients are eligible:
        top_k over the -inf scores of ineligible slots would otherwise
        silently hand back inactive/unregistered (or already-in-flight)
        ids, which downstream code would happily materialize as zero-speed
        phantom clients. The check is a host-side sync on one scalar —
        sampling is a per-round host decision, not inner-loop device code,
        so the sync is free and the failure is loud."""
        mask = self.active
        if available_only:
            mask = jnp.logical_and(mask, jnp.logical_not(self.in_flight))
        pool = int(jnp.sum(mask))
        if size > pool:
            raise ValueError(
                f"sample_cohort: requested {size} clients but only {pool} "
                f"are {'available' if available_only else 'active'} "
                f"(capacity {self.capacity})")
        return _sample_cohort(mask, key, size)

    def mark_in_flight(self, ids, value: bool) -> "ClientStore":
        """Flip the in-flight flag for `ids` (async dispatch/arrival
        bookkeeping — fl/async_rounds.py)."""
        idx = jnp.asarray(ids, jnp.int32)
        return dataclasses.replace(
            self, in_flight=self.in_flight.at[idx].set(bool(value)))

    def update_from_round(self, ids, latencies, rates) -> "ClientStore":
        """Record one round's observations for the cohort `ids`.

        latencies: full-model-equivalent seconds (a rate-r straggler's
        t/r — core/fluid.py computes this); rates: the sub-model size each
        client actually trained (1.0 = full). The first observation seeds
        the EMAs directly."""
        return _update_from_round(self, jnp.asarray(ids, jnp.int32),
                                  jnp.asarray(latencies, jnp.float32),
                                  jnp.asarray(rates, jnp.float32))

    def assign_rates(self, ids, rates) -> "ClientStore":
        """Write calibration output: dropout rate each client trains next."""
        return dataclasses.replace(
            self, dropout_rate=self.dropout_rate.at[
                jnp.asarray(ids, jnp.int32)].set(
                jnp.asarray(rates, jnp.float32)))

    def set_speed(self, ids, speeds) -> "ClientStore":
        """Mutate emulation ground truth (mid-run drift, paper Fig. 4b)."""
        return dataclasses.replace(
            self, speed=self.speed.at[jnp.asarray(ids, jnp.int32)].set(
                jnp.asarray(speeds, jnp.float32)))

    # ------------------------------------------------------ host-side views
    def rates_of(self, ids) -> np.ndarray:
        return np.asarray(self.dropout_rate)[np.asarray(ids, np.int64)]

    def speeds_of(self, ids) -> np.ndarray:
        return np.asarray(self.speed)[np.asarray(ids, np.int64)]

    def shards_of(self, ids) -> np.ndarray:
        return np.asarray(self.data_shard)[np.asarray(ids, np.int64)]

    def last_latency(self, ids) -> np.ndarray:
        """Most recent observed latency per client; NaN if never observed.
        This is what core/straggler.plan_from_store calibrates from."""
        idx = np.asarray(ids, np.int64)
        rp = np.asarray(self.rounds_participated)[idx]
        hist = np.asarray(self.speed_hist)[idx]
        pos = (rp - 1) % self.history
        out = hist[np.arange(idx.size), pos].astype(np.float64)
        out[rp == 0] = np.nan
        return out


@functools.partial(jax.jit, static_argnames=("size",))
def _sample_cohort(mask, key, size: int) -> jnp.ndarray:
    """Gumbel top-k over an eligibility mask. The Gumbel field depends only
    on (key, capacity), so the same key yields the same cohort on any
    device count — and adding exclusions (in-flight clients) only removes
    candidates, it never reshuffles the scores of the rest."""
    g = jax.random.gumbel(key, mask.shape, jnp.float32)
    score = jnp.where(mask, g, -jnp.inf)
    _, ids = jax.lax.top_k(score, size)
    return jnp.sort(ids).astype(jnp.int32)


@jax.jit
def _update_from_round(store: ClientStore, ids, lat, rates) -> ClientStore:
    pos = store.rounds_participated[ids] % store.history
    first = store.rounds_participated[ids] == 0
    was_straggler = (rates < 1.0).astype(jnp.float32)
    ema = jnp.where(first, lat,
                    (1.0 - _EMA) * store.speed_ema[ids] + _EMA * lat)
    sema = jnp.where(first, was_straggler,
                     (1.0 - _EMA) * store.straggler_ema[ids]
                     + _EMA * was_straggler)
    return dataclasses.replace(
        store,
        speed_hist=store.speed_hist.at[ids, pos].set(lat),
        speed_ema=store.speed_ema.at[ids].set(ema),
        straggler_ema=store.straggler_ema.at[ids].set(sema),
        rounds_participated=store.rounds_participated.at[ids].add(1),
    )


# ---------------------------------------------------------------------------
# Population speed model (vectorized form of simulation.default_speeds)

def population_speeds(n: int, straggler_frac: float = 0.1,
                      base: float = 10.0, slow_factor: float = 1.3,
                      seed: int = 0) -> np.ndarray:
    """Per-epoch seconds for a whole population: a clustered fast majority
    plus a `straggler_frac` slow minority at slow_factor x base (paper
    Fig. 4a's 10-32% slower phones). Noise is clipped so the fast cluster
    never overlaps the slow band — gap detection stays well-posed in any
    sampled cohort."""
    rng = np.random.RandomState(seed)
    speeds = base * (1.0 + 0.05 * np.clip(rng.randn(n), -2.5, 2.5))
    slow = rng.rand(n) < straggler_frac
    speeds[slow] = base * slow_factor
    return speeds.astype(np.float32)


# ---------------------------------------------------------------------------
# Round driver: store -> cohort -> backend -> FluidServer -> store

@dataclass
class PopulationConfig:
    """A population-scale experiment: registry size, per-round cohort, and
    which RoundBackend executes the cohort."""
    n_clients: int = 100_000
    cohort_size: int = 100
    workload: str = "synth"
    backend: str = "fleet"            # fl.rounds.BACKEND_NAMES
                                      # ("async" => AsyncPopulationSim)
    policy: str = "invariant"
    n_shards: Optional[int] = None    # sharded_fleet: logical shards (None
                                      # => one per mesh device)
    n_partitions: int = 64            # dataset shards clients map onto
    samples_per_partition: int = 100
    straggler_frac_pop: float = 0.1   # fraction of the population that is slow
    slow_factor: float = 1.3
    base_speed: float = 10.0
    local_epochs: int = 1
    fixed_rate: Optional[float] = None
    straggler_frac: Optional[float] = None   # detection override (None=gap)
    use_kernels: bool = False
    history: int = DEFAULT_HISTORY
    tail_sigma: float = 0.0           # client-side lognormal latency tail
    async_cfg: Optional[object] = None  # fl.async_rounds.AsyncConfig when
                                        # backend == "async"
    seed: int = 0


class PopulationSim:
    """Drives FLuID rounds against a ClientStore.

    Each round: fold the round index into the base key, sample a cohort
    from the store, materialize FleetClients over the cohort's data shards
    (with the store's current ground-truth speeds, so drift applied via
    `set_speed` is visible to the *next* sample), build the configured
    RoundBackend, and run one FluidServer round — which records latencies
    back into the store and re-plans dropout rates from its history.
    """

    def __init__(self, cfg: PopulationConfig, store: ClientStore,
                 server: FluidServer, model_cls, ds, partitions,
                 lr: float, batch_size: int, mesh=None):
        self.cfg = cfg
        self.server = server
        self.model_cls = model_cls
        self.ds = ds
        self._parts = partitions          # list of index arrays into ds
        self.lr = lr
        self.batch_size = batch_size
        self.mesh = mesh
        self._key = jax.random.PRNGKey(cfg.seed)
        self._store_ref = store           # server owns the live store

    # ------------------------------------------------------------- state
    @property
    def store(self) -> ClientStore:
        return self.server.store

    def set_speed(self, client_id: int, speed: float):
        """Drift emulation: visible to the next cohort sample + round."""
        self.server.store = self.server.store.set_speed([client_id], [speed])

    # ------------------------------------------------------------- round
    def cohort_ids(self, rnd: Optional[int] = None) -> np.ndarray:
        rnd = self.server.round if rnd is None else rnd
        key = jax.random.fold_in(self._key, rnd)
        return np.asarray(self.store.sample_cohort(key, self.cfg.cohort_size))

    def _materialize(self, ids: np.ndarray) -> List:
        from repro.fl.client import FleetClient
        speeds = self.store.speeds_of(ids)
        shards = self.store.shards_of(ids)
        seed = self.cfg.seed + 65537 * self.server.round
        return [FleetClient(int(cid), self.model_cls,
                            self.ds.x[self._parts[s]],
                            self.ds.y[self._parts[s]],
                            speed=float(sp), batch_size=self.batch_size,
                            lr=self.lr, local_epochs=self.cfg.local_epochs,
                            tail_sigma=self.cfg.tail_sigma, seed=seed)
                for cid, sp, s in zip(ids, speeds, shards)]

    def run_round(self, eval_now: bool = False):
        from repro.fl.rounds import make_backend
        ids = self.cohort_ids()
        clients = self._materialize(ids)
        backend = make_backend(self.cfg.backend, self.model_cls, clients,
                               self.model_cls.UNIT_SPECS,
                               use_kernels=self.cfg.use_kernels,
                               mesh=self.mesh, n_shards=self.cfg.n_shards)
        return self.server.run_round(eval_now=eval_now, backend=backend)

    def run(self, rounds: int, eval_every: int = 0):
        for i in range(rounds):
            ev = bool(eval_every) and ((i + 1) % eval_every == 0
                                       or i == rounds - 1)
            self.run_round(eval_now=ev)
        return self.server.history


def build_population(cfg: PopulationConfig, mesh=None) -> PopulationSim:
    """Assemble store + dataset + FluidServer for a population run.

    Data: `n_partitions` IID partitions of a `workload` dataset; every
    client maps onto one partition (many-to-one), so 10^5 clients share
    O(n_partitions) resident arrays and any cohort has identical shard
    shapes — the property that keeps the cohort program single-trace
    across rounds."""
    # late import: simulation imports this module for the ClientStore
    from repro.data.partition import partition_iid
    from repro.data.synthetic import make_dataset
    from repro.fl.rounds import BACKEND_NAMES
    from repro.fl.simulation import WORKLOADS
    from repro.models.kernel_models import KERNEL_MODELS
    from repro.models.small import MODELS

    if cfg.backend not in BACKEND_NAMES:
        raise ValueError(f"backend must be one of {BACKEND_NAMES}, "
                         f"got {cfg.backend!r}")
    if cfg.async_cfg is not None and cfg.backend != "async":
        raise ValueError("async_cfg only applies to backend='async'")
    if cfg.backend == "async" and cfg.n_shards is not None:
        raise ValueError("backend='async' does not shard (dispatch groups "
                         "are buffer_k-sized fleet programs)")
    ds_name, model_name, lr, bs = WORKLOADS[cfg.workload]
    model_cls = (MODELS[model_name] if model_name in MODELS
                 else KERNEL_MODELS[model_name])
    n_data = cfg.n_partitions * cfg.samples_per_partition
    ds = make_dataset(ds_name, n=n_data, n_test=max(400, n_data // 5),
                      n_partitions=cfg.n_partitions, seed=cfg.seed)
    parts = partition_iid(ds, cfg.n_partitions, seed=cfg.seed)

    rng_speeds = population_speeds(cfg.n_clients, cfg.straggler_frac_pop,
                                   base=cfg.base_speed,
                                   slow_factor=cfg.slow_factor,
                                   seed=cfg.seed)
    shard_rng = np.random.RandomState(cfg.seed + 1)
    shards = shard_rng.randint(0, cfg.n_partitions, size=cfg.n_clients)
    store = ClientStore.empty(cfg.n_clients, history=cfg.history).register(
        np.arange(cfg.n_clients), rng_speeds, shards)

    params = model_cls.init(jax.random.PRNGKey(cfg.seed))
    xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)

    def eval_fn(p):
        logits = model_cls.apply(p, xt)
        return float((jnp.argmax(logits, -1) == yt).mean())

    fcfg = FluidConfig(method=cfg.policy, fixed_rate=cfg.fixed_rate,
                       straggler_frac=cfg.straggler_frac, seed=cfg.seed)
    server = FluidServer(params, model_cls.UNIT_SPECS, cfg=fcfg,
                         eval_fn=eval_fn, store=store)
    sim = PopulationSim(cfg, store, server, model_cls, ds, parts,
                        lr=lr, batch_size=bs, mesh=mesh)
    if cfg.backend == "async":
        from repro.fl.async_rounds import AsyncPopulationSim
        return AsyncPopulationSim(sim)
    return sim
