"""Sharded cohort executor: the fleet program under shard_map.

fl/fleet.py runs one vmapped masked-SGD program for a whole cohort on one
device. This module scales that same program over the ``data`` axis of a
launch/mesh.py mesh: the cohort is split into `n_shards` logical shards of
equal size, each device runs its shards through the *identical* inner
cohort program, reduces each shard to the masked-FedAvg sufficient
statistics (core/aggregate.partial_sums), and a single `jax.lax.psum`
finishes the hierarchical aggregation. Params and the MaskBank are
replicated (in_specs P()); only per-client tensors are sharded.

Determinism contract: the logical shard count S is part of the *numerical*
program, independent of the device count D (each device owns S/D shards).
Two implementation choices make per-shard arithmetic reproduce bit-for-bit
across device counts, and both were found empirically (tests/
test_population.py locks them in):

  * The local shards are a *Python-unrolled* loop, not jax.lax.map — the
    loop body compiles in a different fusion context for length-2 vs
    length-1 scans, which perturbs the per-shard deltas by 1 ULP.
  * Each shard's partials pass through jax.lax.optimization_barrier AND
    are materialized as a program output (`shard_partials` on the result).
    The barrier keeps the cross-shard reduction out of the per-shard
    tensordots; the output forces each shard's partials into its own
    buffer, which stops XLA from horizontally merging the co-resident
    tensordot instances on low device counts (the merge retiles the
    contraction and moves `num` by 1 ULP — observed with the barrier
    alone). The materialized partials are S param-trees — noise next to
    the (C, ...) deltas — and double as the inspection point for the
    hierarchical-aggregation tests.

The cross-shard reduction is then a fixed left-to-right add chain locally
plus a psum across devices — a two-term psum is bitwise equal to the plain
add (verified directly) — so runs whose reduction trees coincide are
bitwise identical. In particular S=2 on D=1 (local a0+a1) and on D=2
(two-term psum) produce bit-identical aggregated params. For general
(S, D) the association differs and results agree only up to float
summation order — the same caveat as fleet vs sequential.

Everything else (mask bank construction, sim-time draws, CohortResult
views) is inherited from FleetEngine; only `_execute` changes, plus an
`aggregate` that applies the already-reduced partials instead of
recomputing them from gathered deltas.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.aggregate import combine_partials, partial_sums
from repro.fl.client import FleetClient
from repro.fl.fleet import CohortResult, FleetEngine, _cohort_fn
from repro.kernels.ops import _default_interpret
from repro.launch.mesh import make_host_mesh

_SHARDED_CACHE: Dict[tuple, callable] = {}

_combine = jax.jit(combine_partials)


def _tree_add(t1, t2):
    return jax.tree.map(jnp.add, t1, t2)


def _sharded_cohort_fn(model_cls, mesh, n_shards: int,
                       use_kernels: bool, interpret: bool):
    """One compiled program per (model, mesh, shard count): masked local SGD
    for all shards + hierarchical masked-FedAvg partials.

    Signature: run(params, bank, idx, xs, ys, sw, lrs, w, n_steps) where
    per-client operands carry leading (S, Cs) dims. Returns
    (deltas (S, Cs, ...), shard_partials ((S, ...) num tree + (S, K)
    weights), num tree (param shapes), w_per_mask (K,)) with num/
    w_per_mask already fully reduced (replicated on every device).
    """
    key = (model_cls.__name__, mesh, n_shards, use_kernels, interpret)
    if key not in _SHARDED_CACHE:
        inner = _cohort_fn(model_cls, use_kernels, interpret)
        d_dev = mesh.shape["data"]
        local = n_shards // d_dev      # shards per device

        @functools.partial(jax.jit, static_argnames=("n_steps",))
        def run(params, bank, idx, xs, ys, sw, lrs, w, n_steps):
            k = jax.tree.leaves(bank)[0].shape[0]

            def body(p, b, mi, x, y, v, lr, wv):
                # block-local leaves: (local, Cs, ...). The shard loop is
                # Python-unrolled on purpose (bounded by S/D) and each
                # shard's partials are barriered + materialized — see the
                # determinism contract in the module docstring.
                ds, parts = [], []
                for s in range(local):
                    d = inner(p, b, mi[s], x[s], y[s], v[s], lr[s], n_steps)
                    parts.append(jax.lax.optimization_barrier(
                        partial_sums(d, wv[s], mi[s], k)))
                    ds.append(d)
                d = jax.tree.map(lambda *a: jnp.stack(a), *ds)
                pr = jax.tree.map(lambda *a: jnp.stack(a), *parts)
                # fixed left-to-right chain: explicit program structure,
                # not a rewritable reduction
                num, wpm = functools.reduce(_tree_add, parts)
                num = jax.tree.map(lambda a: jax.lax.psum(a, "data"), num)
                wpm = jax.lax.psum(wpm, "data")
                return d, pr, num, wpm

            f = shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(), P("data"), P("data"), P("data"),
                          P("data"), P("data"), P("data")),
                out_specs=(P("data"), P("data"), P(), P()),
                check_rep=False)   # 0.4.x replication inference is
            #                        conservative here; psum makes num/wpm
            #                        replicated by construction
            return f(params, bank, idx, xs, ys, sw, lrs, w)
        _SHARDED_CACHE[key] = run
    return _SHARDED_CACHE[key]


@dataclass
class ShardedCohortResult(CohortResult):
    """CohortResult + the hierarchically-reduced aggregation partials."""
    num: Optional[dict] = None            # tree of param-shaped sums
    w_per_mask: Optional[jnp.ndarray] = None   # (K,)
    shard_partials: Optional[tuple] = None     # ((S, ...) num, (S, K) w)

    def aggregate(self, global_params):
        """Apply the psum-reduced partials (core/aggregate.combine_partials)
        — no second pass over the (C, ...) deltas."""
        return _combine(global_params, self.num, self.w_per_mask,
                        self.mask_bank)


class ShardedFleetEngine(FleetEngine):
    """FleetEngine whose cohort program runs under shard_map.

    n_shards: logical shard count S (defaults to the mesh's data-axis
    size). Requirements, loudly enforced: S divides the cohort size and the
    data-axis device count divides S. The (S, Cs) layout is row-major in
    client order, so shard s holds clients [s*Cs, (s+1)*Cs).
    """

    def __init__(self, model_cls, clients: Sequence[FleetClient], unit_specs,
                 mesh=None, n_shards: Optional[int] = None,
                 use_kernels: bool = False):
        super().__init__(model_cls, clients, unit_specs,
                         use_kernels=use_kernels)
        if mesh is None:
            mesh = make_host_mesh(data=len(jax.devices()))
        if "data" not in mesh.axis_names:
            raise ValueError(f"mesh needs a 'data' axis, got "
                             f"{mesh.axis_names}")
        d_dev = mesh.shape["data"]
        n_shards = d_dev if n_shards is None else int(n_shards)
        if n_shards % d_dev:
            raise ValueError(
                f"n_shards={n_shards} must be a multiple of the mesh's "
                f"data-axis size {d_dev} (each device owns S/D shards)")
        c = len(self.clients)
        if c % n_shards:
            raise ValueError(
                f"cohort size {c} must divide evenly into n_shards="
                f"{n_shards} (equal shards keep one compiled shape)")
        self.mesh = mesh
        self.n_shards = n_shards
        self._sharded = _sharded_cohort_fn(
            model_cls, mesh, n_shards, self.use_kernels,
            interpret=_default_interpret())

    def _execute(self, params, bank, idx, xs, ys, sw, lrs, weights):
        s, cs = self.n_shards, len(self.clients) // self.n_shards

        def resh(a):
            return a.reshape((s, cs) + a.shape[1:])
        d, pr, num, wpm = self._sharded(params, bank, resh(idx), resh(xs),
                                        resh(ys), resh(sw), resh(lrs),
                                        resh(weights), self.steps)
        deltas = jax.tree.map(
            lambda a: a.reshape((s * cs,) + a.shape[2:]), d)
        return deltas, (num, wpm, pr)

    def _wrap_result(self, extra, **kw) -> ShardedCohortResult:
        num, wpm, pr = extra
        return ShardedCohortResult(num=num, w_per_mask=wpm,
                                   shard_partials=pr, **kw)
