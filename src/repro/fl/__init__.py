from repro.fl.client import FleetClient, SimClient
from repro.fl.fleet import CohortResult, FleetEngine
from repro.fl.population import (ClientStore, PopulationConfig,
                                 PopulationSim, build_population,
                                 population_speeds)
from repro.fl.rounds import (BACKEND_NAMES, FleetBackend, RoundBackend,
                             SequentialBackend, ShardedFleetBackend,
                             make_backend)
from repro.fl.shard_fleet import ShardedCohortResult, ShardedFleetEngine
from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation, run_experiment)
