from repro.fl.client import FleetClient, SimClient
from repro.fl.fleet import CohortResult, FleetEngine
from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation, run_experiment)
