from repro.fl.client import FleetClient, SimClient
from repro.fl.fleet import CohortResult, FleetEngine
from repro.fl.simulation import build_simulation, run_experiment
