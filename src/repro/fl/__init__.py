from repro.fl.client import SimClient
from repro.fl.simulation import build_simulation, run_experiment
