"""RoundBackend protocol: one round-execution contract, three engines.

core/fluid.FluidServer used to special-case "engine or per-client loop"
inline. The population layer needs a third execution mode (sharded fleet)
and per-round backends (every cohort is a fresh client list sampled from
the ClientStore), so the execution strategies are now first-class objects
behind one small protocol:

    backend.clients                       -> the cohort (ordered)
    backend.run_round(params, keep_maps, rates) -> result with
        .sim_times               {cid: emulated seconds}
        .aggregate(params)       -> new global params (masked FedAvg)
        .non_straggler_stats(prev) -> per-client invariant-neuron stats
        .updates()               -> sequential-style ClientUpdates

SequentialBackend is the numerical reference (one jit call per client,
physically extracted sub-models); FleetBackend runs the whole cohort as
one vmapped program (fl/fleet.py); ShardedFleetBackend runs that same
program under shard_map over a mesh's data axis (fl/shard_fleet.py). All
three agree up to float summation order (tests/test_population.py,
tests/test_fleet.py). AsyncBufferedBackend (fl/async_rounds.py) drops the
barrier entirely: `run_round` dispatches the cohort and then drains the
first K *arrivals* off the EventLoop below — round membership becomes
data-dependent, and the result carries staleness per arrival.

This module also owns the virtual clock those arrivals ride on: EventLoop
is a deterministic (time, push-order) heap, so a zero-latency-spread run
resolves ties in dispatch order and the whole async schedule reproduces
from the seeds alone.
"""
from __future__ import annotations

import heapq

from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import jax
import numpy as np

from repro.core import invariant as inv
from repro.core import submodel as sub
from repro.core.aggregate import ClientUpdate, aggregate
from repro.fl.fleet import FleetEngine
from repro.fl.shard_fleet import ShardedFleetEngine

BACKEND_NAMES = ("sequential", "fleet", "sharded_fleet", "async")


class EventLoop:
    """Virtual-clock event queue for emulated asynchrony.

    `push(t, payload)` schedules; `pop()` returns the earliest event and
    advances `now` monotonically (a pop never rewinds the clock, even if
    an event was scheduled in the past relative to a later dispatch).
    Ties on `t` break by push order — with zero latency spread the async
    backend therefore drains arrivals in exactly the order it dispatched
    them, which the fleet==async equivalence test pins."""

    def __init__(self):
        self._heap: List[Tuple[float, int, object]] = []
        self._seq = 0
        self.now = 0.0

    def push(self, t: float, payload) -> None:
        heapq.heappush(self._heap, (float(t), self._seq, payload))
        self._seq += 1

    def pop(self):
        t, _, payload = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, payload

    def __len__(self) -> int:
        return len(self._heap)


class RoundResult(Protocol):
    sim_times: Dict[int, float]

    def aggregate(self, global_params): ...
    def non_straggler_stats(self, prev_params) -> List[dict]: ...
    def updates(self) -> List[ClientUpdate]: ...


class RoundBackend(Protocol):
    name: str
    clients: Sequence

    def run_round(self, params, keep_maps: Dict[int, dict],
                  rates: Dict[int, float]) -> RoundResult: ...


# ---------------------------------------------------------------------------
# Sequential reference

@dataclass
class SequentialResult:
    """Per-client ClientUpdates presented through the RoundResult contract."""
    _updates: List[ClientUpdate]
    unit_specs: list

    @property
    def sim_times(self) -> Dict[int, float]:
        return {u.client_id: u.sim_time for u in self._updates}

    def aggregate(self, global_params):
        return aggregate(global_params, self._updates)

    def non_straggler_stats(self, prev_params) -> List[dict]:
        return [inv.neuron_stats(prev_params,
                                 jax.tree.map(lambda p, d: p + d,
                                              prev_params, u.delta),
                                 self.unit_specs)
                for u in self._updates if u.mask is None]

    def updates(self) -> List[ClientUpdate]:
        return list(self._updates)


class SequentialBackend:
    """One jit call per client; stragglers train physically extracted
    sub-models (core/submodel.extract) and their deltas are re-embedded in
    full coordinates — the paper-literal reference path."""
    name = "sequential"

    def __init__(self, clients: Sequence, unit_specs):
        self.clients = list(clients)
        self.unit_specs = unit_specs

    def run_round(self, params, keep_maps, rates) -> SequentialResult:
        updates: List[ClientUpdate] = []
        for c in self.clients:
            if c.id in keep_maps:
                keep, r = keep_maps[c.id], rates[c.id]
                sub_params = sub.extract(params, self.unit_specs, keep)
                u = c.train(sub_params, keep_map=keep, rate=r)
                full_delta, mask = sub.embed_delta(
                    u.delta, params, self.unit_specs, keep)
                u = ClientUpdate(full_delta, u.n_samples, mask,
                                 u.sim_time, u.real_time, c.id)
            else:
                u = c.train(params)
            updates.append(u)
        return SequentialResult(updates, self.unit_specs)


# ---------------------------------------------------------------------------
# Fleet backends: CohortResult already satisfies RoundResult

class FleetBackend:
    """The whole cohort as one vmapped masked-SGD program."""
    name = "fleet"

    def __init__(self, engine: FleetEngine):
        self.engine = engine

    @property
    def clients(self):
        return self.engine.clients

    def run_round(self, params, keep_maps, rates):
        return self.engine.run_cohort(params, keep_maps, rates)


class ShardedFleetBackend(FleetBackend):
    """The fleet program under shard_map with hierarchical aggregation."""
    name = "sharded_fleet"

    def __init__(self, engine: ShardedFleetEngine):
        super().__init__(engine)


def make_backend(name: str, model_cls, clients, unit_specs,
                 use_kernels: bool = False, mesh=None,
                 n_shards: Optional[int] = None,
                 async_cfg=None) -> RoundBackend:
    """Construct a RoundBackend for one cohort.

    sharded_fleet resolves its shard count as: explicit n_shards if given,
    else the largest device count that divides the cohort
    (gcd(|cohort|, data-axis devices)) — degenerating to an unsharded
    1-device mesh rather than erroring on awkward cohort sizes.

    "async" constructs an AsyncBufferedBackend with `clients` as its first
    dispatch group. Unlike the synchronous backends it is STATEFUL across
    rounds (virtual clock, in-flight arrival heap, server version) — reuse
    the same instance and re-point `set_dispatch(...)` per round, as
    fl/async_rounds.AsyncPopulationSim does; building a fresh one per
    round silently discards every in-flight client."""
    if name == "async":
        from repro.fl.async_rounds import AsyncBufferedBackend, AsyncConfig
        backend = AsyncBufferedBackend(model_cls, unit_specs,
                                       async_cfg or AsyncConfig(),
                                       use_kernels=use_kernels)
        backend.set_dispatch(clients)
        return backend
    if name == "sequential":
        return SequentialBackend(clients, unit_specs)
    if name == "fleet":
        return FleetBackend(FleetEngine(model_cls, clients, unit_specs,
                                        use_kernels=use_kernels))
    if name == "sharded_fleet":
        if n_shards is None:
            if mesh is not None:
                n_shards = mesh.shape["data"]
            else:
                from repro.launch.mesh import make_host_mesh
                n_shards = int(np.gcd(len(clients), len(jax.devices())))
                mesh = make_host_mesh(data=n_shards)
        return ShardedFleetBackend(
            ShardedFleetEngine(model_cls, clients, unit_specs, mesh=mesh,
                               n_shards=n_shards, use_kernels=use_kernels))
    raise ValueError(f"backend must be one of {BACKEND_NAMES}, got {name!r}")
