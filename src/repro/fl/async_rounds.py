"""Asynchronous buffered rounds: masked FedAvg without the cohort barrier.

The synchronous backends (fl/rounds.py) hold a barrier over the cohort:
even with invariant dropout shrinking straggler sub-models, one slow or
disconnected client bounds wall-clock between calibrations. This backend
drops the barrier, FedBuff-style, while keeping every FLuID invariant-
dropout mechanism intact:

  * clients are DISPATCHED with the current params and the keep-masks the
    store assigned them, in fixed-size groups of `buffer_k` (the last group
    capacity-padded via FleetEngine's partial-cohort `members=` — program
    shapes never depend on how many clients happened to be free);
  * each dispatched client's masked delta is computed eagerly (it depends
    only on the dispatch-time params) and its ARRIVAL is scheduled on a
    virtual clock (fl/rounds.EventLoop) at now + latency, where latency is
    the client speed model's draw passed through the arrival process
    (core/straggler.ArrivalModel: heavy tails, mid-round dropouts that
    reconnect and resume);
  * one "round" = drain the first `buffer_k` arrivals off the clock and
    aggregate them with staleness-weighted masked FedAvg
    (core/aggregate.aggregate_buffered — the same partial_sums /
    combine_partials pipeline as the fleet, with each arrival's weight
    discounted by (1+s)^(-a), max-normalized). A straggler that misses the
    buffer is NOT dropped: its delta stays on the heap and lands in a
    later buffer with staleness = #server versions it missed.

Fixed-shape discipline (DESIGN.md §13): dispatch groups are always exactly
buffer_k clients, the drained buffer is always exactly buffer_k arrivals,
and the rebuilt buffer mask bank deduplicates to the same row count the
dispatch banks had — so at steady state (constant calibration output) the
dispatch program, the stats program, and `aggregate_buffered` each compile
once, whatever arrival order the clock produces. Verified by the
`single-trace-async` contract in repro/analysis/contracts.py.

Determinism ladder (tests/test_async.py): with a zero-spread ArrivalModel
and zero client tail_sigma, arrival order degenerates to dispatch order
(EventLoop breaks time ties by push order), and an async run with
buffer_k = concurrency = cohort_size reproduces the synchronous fleet
run BITWISE — same cohorts, same deltas, same aggregated params, same
calibration plans — because every identity in the chain is exact:
lognormal(0) multiplier == 1.0, staleness 0 ⇒ scale == 1.0, w * 1.0 == w.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import ClientUpdate, aggregate_buffered
from repro.core.straggler import ArrivalModel
from repro.fl.fleet import CohortResult, FleetEngine
from repro.fl.population import PopulationSim
from repro.fl.rounds import EventLoop


@dataclass
class AsyncConfig:
    """Async buffered-round policy.

    buffer_k: arrivals aggregated per server step (and the dispatch-group
    capacity). concurrency: target number of in-flight clients the
    population driver maintains (FedBuff's M); must be >= buffer_k so a
    buffer can always fill. staleness_exponent: the `a` of the (1+s)^(-a)
    discount (0 = ignore staleness). flash_crowds: (server_step, extra)
    pairs — at that step the driver dispatches `extra` clients beyond the
    concurrency target, emulating a reconnect surge; the surplus drains
    back to `concurrency` over the following buffers."""
    buffer_k: int = 8
    concurrency: int = 64
    staleness_exponent: float = 0.5
    arrival: ArrivalModel = field(default_factory=ArrivalModel)
    flash_crowds: Sequence[Tuple[int, int]] = ()

    def __post_init__(self):
        if self.buffer_k < 1:
            raise ValueError(f"buffer_k must be >= 1, got {self.buffer_k}")
        if self.concurrency < self.buffer_k:
            raise ValueError(
                f"concurrency ({self.concurrency}) must be >= buffer_k "
                f"({self.buffer_k}): the buffer could never fill")
        if self.staleness_exponent < 0.0:
            raise ValueError(f"staleness_exponent must be >= 0, "
                             f"got {self.staleness_exponent}")


@dataclass
class _InFlight:
    """One dispatched client riding the event loop: which slot of which
    dispatch-group result it owns, and what the server knew at dispatch."""
    cid: int
    version: int                 # server version at dispatch
    slot: int                    # row in the dispatch group's stacked result
    result: CohortResult         # the (buffer_k,)-shaped dispatch outputs
    latency: float               # end-to-end arrival latency (sim seconds)
    rate: float                  # sub-model size trained
    stats: Optional[dict]        # dispatch-time invariant stats (non-strag)
    drops: int                   # mid-round dropouts survived


@dataclass
class AsyncRoundResult:
    """RoundResult over one drained buffer (fl/rounds.py protocol, plus the
    async-only fields core/fluid.FluidServer reads via getattr: clock,
    staleness, rates_trained, calib_ids)."""
    arrivals: List[_InFlight]    # canonical order: (dispatch version, slot)
    version: int                 # server version aggregating this buffer
    clock: float                 # virtual time when the buffer filled
    exponent: float

    @property
    def sim_times(self) -> Dict[int, float]:
        return {a.cid: a.latency for a in self.arrivals}

    @property
    def rates_trained(self) -> Dict[int, float]:
        """Rate each arrival ACTUALLY trained (assigned at its dispatch) —
        the server must not de-normalize latencies with rates it assigned
        to this step's fresh dispatches."""
        return {a.cid: a.rate for a in self.arrivals}

    @property
    def calib_ids(self) -> List[int]:
        """Who recalibration reasons about: the clients with fresh
        observations, i.e. this buffer's arrivals (sorted, like a cohort)."""
        return sorted(a.cid for a in self.arrivals)

    @property
    def staleness(self) -> np.ndarray:
        return np.asarray([self.version - a.version for a in self.arrivals],
                          np.float32)

    def _buffer_bank(self):
        """Rebuild (bank, idx) over the buffer from the arrivals' dispatch
        banks: all-ones row 0 + one row per distinct straggler mask, in
        first-encounter order over the canonical arrival order. Dedupe key
        is (dispatch result, row): rows of one dispatch bank are distinct
        by construction, and MaskBank already content-deduped within each
        dispatch. Encounter order equals ascending-cid order for a single
        dispatch group, so the rebuilt bank reproduces the dispatch bank
        exactly — the bitwise anchor of the fleet==async equivalence."""
        ones = jax.tree.map(lambda b: b[0], self.arrivals[0].result.mask_bank)
        rows, row_map, idx = [ones], {}, []
        for a in self.arrivals:
            r = int(a.result.mask_idx[a.slot])
            if r == 0:
                idx.append(0)
                continue
            key = (id(a.result), r)
            if key not in row_map:
                row_map[key] = len(rows)
                rows.append(jax.tree.map(lambda b: b[r], a.result.mask_bank))
            idx.append(row_map[key])
        bank = jax.tree.map(lambda *rs: jnp.stack(rs), *rows)
        return bank, jnp.asarray(idx, jnp.int32)

    def aggregate(self, global_params):
        """Staleness-weighted masked FedAvg over the buffer. Deltas arrive
        mask-pre-zeroed from the dispatch programs, so stacking the
        arrivals' rows feeds core/aggregate.aggregate_buffered the exact
        inputs aggregate_stacked would see for a synchronous cohort."""
        deltas = jax.tree.map(
            lambda *rows: jnp.stack(rows),
            *[jax.tree.map(lambda d: d[a.slot], a.result.deltas)
              for a in self.arrivals])
        weights = jnp.stack([a.result.weights[a.slot]
                             for a in self.arrivals])
        bank, idx = self._buffer_bank()
        return aggregate_buffered(global_params, deltas, weights, bank, idx,
                                  self.staleness, self.exponent)

    def non_straggler_stats(self, prev_params) -> List[dict]:
        """Invariant-neuron stats of the buffer's full-model arrivals.
        Computed at DISPATCH time against the dispatch params (the delta's
        own baseline); `prev_params` is ignored — an async server has no
        single "previous params" for a mixed-staleness buffer."""
        del prev_params
        return [a.stats for a in self.arrivals if a.stats is not None]

    def updates(self) -> List[ClientUpdate]:
        out = []
        for a in self.arrivals:
            delta = jax.tree.map(lambda d: d[a.slot], a.result.deltas)
            mask = None
            if a.cid in a.result.straggler_ids:
                row = int(a.result.mask_idx[a.slot])
                mask = jax.tree.map(lambda b: b[row], a.result.mask_bank)
            out.append(ClientUpdate(delta, int(a.result.weights[a.slot]),
                                    mask, a.latency, 0.0, a.cid))
        return out


class AsyncBufferedBackend:
    """RoundBackend without a barrier: dispatch eagerly, aggregate the
    first buffer_k arrivals, keep the rest in flight.

    STATEFUL across rounds (virtual clock, arrival heap, in-flight set,
    server version) — construct once and re-point `set_dispatch` each
    round. `clients` is only the NEXT dispatch group, not the buffer: the
    aggregated clients are whoever arrives first."""
    name = "async"

    def __init__(self, model_cls, unit_specs, cfg: AsyncConfig,
                 use_kernels: bool = False):
        self.model_cls = model_cls
        self.unit_specs = unit_specs
        self.cfg = cfg
        self.use_kernels = bool(use_kernels)
        self.loop = EventLoop()
        self.version = 0
        self.clients: List = []          # next dispatch group
        self.in_flight_ids: set = set()
        self.last_arrived: List[int] = []
        self.last_result: Optional[AsyncRoundResult] = None
        self.n_dispatched = 0
        self.total_drops = 0

    # ------------------------------------------------------------- wiring
    def set_dispatch(self, clients: Sequence) -> None:
        """Point the backend at the next round's dispatch group (clients
        already in flight are skipped at dispatch time)."""
        self.clients = list(clients)

    # ----------------------------------------------------------- dispatch
    def _dispatch_chunk(self, params, chunk, keep_maps, rates, members):
        """Run one capacity-padded dispatch group NOW and schedule its
        arrivals. The delta depends only on the dispatch params, so it is
        computed eagerly; only its *visibility* to the server is delayed."""
        engine = FleetEngine(self.model_cls, chunk, self.unit_specs,
                             use_kernels=self.use_kernels)
        ids_here = {c.id for c in chunk}
        km = {cid: m for cid, m in keep_maps.items() if cid in ids_here}
        res = engine.run_cohort(params, km, rates, members=members)
        stats = res.non_straggler_stats(params)
        stat_slots = [i for i, cid in enumerate(res.client_ids)
                      if cid not in res.straggler_ids
                      and (members is None or members[i])]
        by_slot = dict(zip(stat_slots, stats))
        for slot, c in enumerate(chunk):
            if members is not None and not members[slot]:
                continue
            lat, drops = self.cfg.arrival.draw(res.sim_times[c.id])
            self.loop.push(
                self.loop.now + lat,
                _InFlight(c.id, self.version, slot, res, lat,
                          rates.get(c.id, 1.0), by_slot.get(slot), drops))
            self.in_flight_ids.add(c.id)
            self.n_dispatched += 1
            self.total_drops += drops

    # -------------------------------------------------------------- round
    def run_round(self, params, keep_maps: Dict[int, dict],
                  rates: Dict[int, float]) -> AsyncRoundResult:
        K = self.cfg.buffer_k
        group = [c for c in self.clients if c.id not in self.in_flight_ids]
        for i in range(0, len(group), K):
            chunk = list(group[i:i + K])
            members = None
            if len(chunk) < K:
                members = np.zeros(K, bool)
                members[:len(chunk)] = True
                # pad with clones under reserved negative ids: replace()
                # re-runs __post_init__, so the pads own fresh RNG streams
                # and the real clients' draws are untouched (not that a
                # pad ever draws — it runs 0 steps and no sim time)
                chunk += [dataclasses.replace(chunk[0], id=-(j + 1))
                          for j in range(K - len(chunk))]
            self._dispatch_chunk(params, chunk, keep_maps, rates, members)
        if len(self.loop) < K:
            raise RuntimeError(
                f"async buffer cannot fill: buffer_k={K} but only "
                f"{len(self.loop)} clients in flight — raise concurrency "
                f"or dispatch more clients")
        arrivals = [self.loop.pop()[1] for _ in range(K)]
        clock = self.loop.now
        # canonical aggregation order: (dispatch version, slot) — stable
        # whatever order the clock delivered, and equal to client order
        # for a single fresh dispatch group (the sync-equivalence anchor)
        arrivals.sort(key=lambda a: (a.version, a.slot))
        for a in arrivals:
            self.in_flight_ids.discard(a.cid)
        self.last_arrived = [a.cid for a in arrivals]
        result = AsyncRoundResult(arrivals, self.version, clock,
                                  self.cfg.staleness_exponent)
        self.version += 1
        self.last_result = result
        return result


# ---------------------------------------------------------------------------
# Population driver

class AsyncPopulationSim(PopulationSim):
    """PopulationSim whose rounds are arrival buffers, not barriers.

    Each round: top the in-flight pool back up to `concurrency` by
    sampling ONLY available clients (active and not in flight — the
    store's in_flight flags are the arrival bookkeeping), dispatch them
    with the store's current rate assignments, drain one buffer, and let
    FluidServer record observations/recalibrate over the ARRIVED clients.
    Flash crowds dispatch extra clients at configured steps. Built via
    `build_population(PopulationConfig(backend="async", async_cfg=...))`.
    """

    def __init__(self, base: PopulationSim):
        self.__dict__.update(base.__dict__)
        self.acfg: AsyncConfig = self.cfg.async_cfg or AsyncConfig()
        if self.acfg.concurrency > self.cfg.n_clients:
            raise ValueError(
                f"concurrency ({self.acfg.concurrency}) exceeds the "
                f"population ({self.cfg.n_clients})")
        self.backend = AsyncBufferedBackend(
            self.model_cls, self.model_cls.UNIT_SPECS, self.acfg,
            use_kernels=self.cfg.use_kernels)

    @property
    def clock(self) -> float:
        """Virtual seconds elapsed (the async analogue of summing the
        synchronous per-round barrier times)."""
        return self.backend.loop.now

    def run_round(self, eval_now: bool = False):
        rnd = self.server.round
        need = self.acfg.concurrency - len(self.backend.in_flight_ids)
        need += sum(extra for step, extra in self.acfg.flash_crowds
                    if step == rnd)
        need = max(0, need)
        if need:
            key = jax.random.fold_in(self._key, rnd)
            ids = np.asarray(self.store.sample_cohort(key, need,
                                                      available_only=True))
            clients = self._materialize(ids)
            self.server.store = self.server.store.mark_in_flight(ids, True)
        else:
            clients = []
        self.backend.set_dispatch(clients)
        log = self.server.run_round(eval_now=eval_now, backend=self.backend)
        self.server.store = self.server.store.mark_in_flight(
            np.asarray(self.backend.last_arrived, np.int32), False)
        return log


def build_async_population(cfg, acfg: Optional[AsyncConfig] = None,
                           mesh=None) -> AsyncPopulationSim:
    """Convenience wrapper: `build_population` with backend='async'."""
    from repro.fl.population import build_population
    cfg = dataclasses.replace(cfg, backend="async",
                              async_cfg=acfg if acfg is not None
                              else cfg.async_cfg)
    return build_population(cfg, mesh=mesh)
