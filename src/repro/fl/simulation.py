"""End-to-end FL simulation assembly: data -> clients -> FluidServer.

`build_simulation` wires a paper workload (femnist/cifar10/shakespeare) to a
client fleet with a chosen heterogeneity profile; `run_experiment` is the
one-call driver used by benchmarks and examples.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fluid import FluidConfig, FluidServer
from repro.data.partition import partition_non_iid
from repro.data.synthetic import make_dataset
from repro.fl.client import FleetClient, SimClient
from repro.fl.fleet import FleetEngine
from repro.models.small import MODELS

BACKENDS = ("sequential", "fleet")

WORKLOADS = {
    "femnist": ("femnist", "femnist_cnn", 0.004, 10),
    "cifar10": ("cifar10", "cifar_vgg9", 0.01, 20),
    "shakespeare": ("shakespeare", "shakespeare_lstm", 0.001, 32),
}


@dataclass
class Simulation:
    server: FluidServer
    clients: List[SimClient]
    model_cls: type
    ds: object
    backend: str = "sequential"

    def set_speed(self, client_id: int, speed: float):
        """Emulate runtime condition changes (paper Fig. 4b)."""
        for c in self.clients:
            if c.id == client_id:
                c.speed = speed
                return
        raise KeyError(client_id)


def default_speeds(n_clients: int, straggler_ids: Sequence[int],
                   base: float = 10.0, slow_factor: float = 1.3,
                   seed: int = 0) -> Dict[int, float]:
    """Per-epoch seconds mirroring the paper's phone fleet: clustered
    non-stragglers + slow_factor x stragglers (10-32% slower, Fig. 4a)."""
    rng = np.random.RandomState(seed)
    speeds = {i: base * (1.0 + 0.05 * rng.randn()) for i in range(n_clients)}
    for s in straggler_ids:
        speeds[s] = base * slow_factor
    return speeds


def build_simulation(workload: str, n_clients: int = 5,
                     straggler_ids: Sequence[int] = (0,),
                     method: str = "invariant",
                     fixed_rate: Optional[float] = None,
                     straggler_frac: Optional[float] = None,
                     slow_factor: float = 1.3,
                     n_data: int = 2000, local_epochs: int = 1,
                     seed: int = 0, speeds: Optional[Dict] = None,
                     backend: str = "sequential") -> Simulation:
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    ds_name, model_name, lr, bs = WORKLOADS[workload]
    model_cls = MODELS[model_name]
    ds = make_dataset(ds_name, n=n_data, n_test=max(400, n_data // 5),
                      n_partitions=max(n_clients * 2, 16), seed=seed)
    parts = partition_non_iid(ds, n_clients, seed=seed)
    if speeds is None:
        speeds = default_speeds(n_clients, straggler_ids,
                                slow_factor=slow_factor, seed=seed)
    client_cls = FleetClient if backend == "fleet" else SimClient
    clients = [client_cls(i, model_cls, ds.x[parts[i]], ds.y[parts[i]],
                          speed=speeds[i], batch_size=bs, lr=lr,
                          local_epochs=local_epochs, seed=seed)
               for i in range(n_clients)]
    params = model_cls.init(jax.random.PRNGKey(seed))

    xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)

    def eval_fn(p):
        logits = model_cls.apply(p, xt)
        return float((jnp.argmax(logits, -1) == yt).mean())

    cfg = FluidConfig(method=method, fixed_rate=fixed_rate,
                      straggler_frac=straggler_frac, seed=seed)
    engine = (FleetEngine(model_cls, clients, model_cls.UNIT_SPECS)
              if backend == "fleet" else None)
    server = FluidServer(params, model_cls.UNIT_SPECS, clients, cfg,
                         eval_fn=eval_fn, engine=engine)
    return Simulation(server, clients, model_cls, ds, backend)


def run_experiment(workload: str, rounds: int, **kw):
    eval_every = kw.pop("eval_every", max(1, rounds // 5))
    sim = build_simulation(workload, **kw)
    hist = sim.server.run(rounds, eval_every=eval_every)
    return sim, hist
