"""End-to-end FL simulation assembly: data -> ClientStore -> FluidServer.

Experiments are described by a typed `SimulationConfig` (workload, backend,
policy, cohort composition, speed model) so configs can be constructed
programmatically, validated up front, and carry per-client heterogeneity
(learning rates, local-epoch counts) that the fleet backends execute as
vmapped data. The legacy ``build_simulation(workload, **kwargs)`` call
shape (deprecated in PR 2) has been removed — `build_simulation` takes a
SimulationConfig, full stop.

Every simulation owns a ClientStore (fl/population.py) with one slot per
client: speeds live there (set_speed writes through), round latencies are
recorded there, and straggler recalibration reads the store's speed
history — the same data path the population-scale driver uses, just with a
cohort that happens to equal the whole registry.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dropout import available_policies
from repro.core.fluid import FluidConfig, FluidServer
from repro.data.partition import partition_non_iid
from repro.data.synthetic import make_dataset
from repro.fl.client import FleetClient, SimClient
from repro.fl.population import ClientStore
from repro.fl.rounds import BACKEND_NAMES, make_backend
from repro.models.kernel_models import KERNEL_MODELS
from repro.models.small import MODELS

# The small-cohort simulation drives synchronous barriers only; the async
# buffered backend is stateful across rounds and needs the population
# driver's in-flight bookkeeping (fl/async_rounds.AsyncPopulationSim).
BACKENDS = tuple(n for n in BACKEND_NAMES if n != "async")

WORKLOADS = {
    "femnist": ("femnist", "femnist_cnn", 0.004, 10),
    "cifar10": ("cifar10", "cifar_vgg9", 0.01, 20),
    "shakespeare": ("shakespeare", "shakespeare_lstm", 0.001, 32),
    # kernel-capable variants: same datasets, models whose masked matmuls
    # can route through the Pallas kernels (use_kernels=True, fleet only)
    "femnist_kernel": ("femnist", "kernel_mlp", 0.02, 10),
    "femnist_attn": ("femnist", "kernel_attn", 0.02, 10),
    # population-scale workload: 32-dim vector MLP, small enough that a
    # 5k-client cohort's stacked batches stay ~64 MB (benchmarks/
    # population_bench.py)
    "synth": ("synth", "synth_mlp", 0.05, 20),
}


@dataclass
class CohortConfig:
    """Who trains: fleet composition + per-client hyperparameters.

    `local_epochs` and `lr` accept either one value for the whole cohort or
    a length-n_clients sequence; heterogeneous values are plain data to the
    fleet backend (one compiled program either way). `lr=None` defers to the
    workload's paper default."""
    n_clients: int = 5
    straggler_ids: Sequence[int] = (0,)
    local_epochs: Union[int, Sequence[int]] = 1
    lr: Union[None, float, Sequence[float]] = None
    n_data: int = 2000
    slow_factor: float = 1.3

    def _per_client(self, val, default, name: str) -> list:
        if val is None:
            val = default
        if np.ndim(val) == 0:
            return [type(default)(val)] * self.n_clients
        vals = list(val)
        if len(vals) != self.n_clients:
            raise ValueError(f"{name} must be a scalar or length "
                             f"{self.n_clients}, got length {len(vals)}")
        return [type(default)(v) for v in vals]

    def client_lrs(self, default_lr: float) -> List[float]:
        return self._per_client(self.lr, default_lr, "lr")

    def client_epochs(self) -> List[int]:
        return self._per_client(self.local_epochs, 1, "local_epochs")


@dataclass
class SimulationConfig:
    """A complete experiment description: workload x backend x dropout
    policy x cohort, plus the straggler speed model."""
    workload: str = "femnist"
    backend: str = "sequential"            # see BACKENDS
    policy: str = "invariant"              # see core.dropout.available_policies
    cohort: CohortConfig = field(default_factory=CohortConfig)
    speeds: Optional[Dict[int, float]] = None   # None => default_speeds()
    fixed_rate: Optional[float] = None
    straggler_frac: Optional[float] = None
    use_kernels: bool = False     # fleet backend: route masked matmuls
    n_shards: Optional[int] = None  # sharded_fleet: logical shard count
    seed: int = 0                 # through the Pallas kernel path (§10)

    def __post_init__(self):
        if self.use_kernels and self.backend != "fleet":
            raise ValueError("use_kernels=True requires backend='fleet' "
                             "(the kernel path lives in the cohort program)")
        if self.workload not in WORKLOADS:
            raise ValueError(f"workload must be one of "
                             f"{tuple(WORKLOADS)}, got {self.workload!r}")
        if self.backend == "async":
            raise ValueError(
                "backend='async' is population-scale only — use "
                "build_population(PopulationConfig(backend='async', "
                "async_cfg=AsyncConfig(...)))")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.policy != "none" and self.policy not in available_policies():
            raise ValueError(f"unknown dropout policy {self.policy!r}; "
                             f"available: {available_policies()} or 'none'")
        if self.n_shards is not None and self.backend != "sharded_fleet":
            raise ValueError("n_shards only applies to backend="
                             "'sharded_fleet'")


@dataclass
class Simulation:
    server: FluidServer
    clients: List[SimClient]
    model_cls: type
    ds: object
    backend: str = "sequential"

    @property
    def store(self) -> ClientStore:
        """The simulation's ClientStore (slot i == client i)."""
        return self.server.store

    def set_speed(self, client_id: int, speed: float):
        """Emulate runtime condition changes (paper Fig. 4b). Writes through
        to the ClientStore, so recalibration and any later cohort sampling
        see the drift immediately — the client object and the store cannot
        go stale relative to each other."""
        for c in self.clients:
            if c.id == client_id:
                c.speed = speed
                self.server.store = self.server.store.set_speed(
                    [client_id], [speed])
                return
        raise KeyError(client_id)


def default_speeds(n_clients: int, straggler_ids: Sequence[int],
                   base: float = 10.0, slow_factor: float = 1.3,
                   seed: int = 0) -> Dict[int, float]:
    """Per-epoch seconds mirroring the paper's phone fleet: clustered
    non-stragglers + slow_factor x stragglers (10-32% slower, Fig. 4a).
    One vectorized draw — the same RandomState stream as the historical
    per-client loop, so seeds reproduce old runs."""
    rng = np.random.RandomState(seed)
    vals = base * (1.0 + 0.05 * rng.randn(n_clients))
    speeds = {i: float(vals[i]) for i in range(n_clients)}
    for s in straggler_ids:
        speeds[s] = base * slow_factor
    return speeds


def _build(cfg: SimulationConfig) -> Simulation:
    co = cfg.cohort
    ds_name, model_name, lr, bs = WORKLOADS[cfg.workload]
    model_cls = (MODELS[model_name] if model_name in MODELS
                 else KERNEL_MODELS[model_name])
    ds = make_dataset(ds_name, n=co.n_data, n_test=max(400, co.n_data // 5),
                      n_partitions=max(co.n_clients * 2, 16), seed=cfg.seed)
    parts = partition_non_iid(ds, co.n_clients, seed=cfg.seed)
    speeds = cfg.speeds
    if speeds is None:
        speeds = default_speeds(co.n_clients, co.straggler_ids,
                                slow_factor=co.slow_factor, seed=cfg.seed)
    lrs = co.client_lrs(lr)
    epochs = co.client_epochs()
    client_cls = SimClient if cfg.backend == "sequential" else FleetClient
    clients = [client_cls(i, model_cls, ds.x[parts[i]], ds.y[parts[i]],
                          speed=speeds[i], batch_size=bs, lr=lrs[i],
                          local_epochs=epochs[i], seed=cfg.seed)
               for i in range(co.n_clients)]
    params = model_cls.init(jax.random.PRNGKey(cfg.seed))

    xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)

    def eval_fn(p):
        logits = model_cls.apply(p, xt)
        return float((jnp.argmax(logits, -1) == yt).mean())

    # one store slot per client: speeds + latency history + assigned rates
    store = ClientStore.empty(co.n_clients).register(
        np.arange(co.n_clients),
        np.asarray([speeds[i] for i in range(co.n_clients)], np.float32),
        np.arange(co.n_clients))

    fcfg = FluidConfig(method=cfg.policy, fixed_rate=cfg.fixed_rate,
                       straggler_frac=cfg.straggler_frac, seed=cfg.seed)
    backend = make_backend(cfg.backend, model_cls, clients,
                           model_cls.UNIT_SPECS, use_kernels=cfg.use_kernels,
                           n_shards=cfg.n_shards)
    server = FluidServer(params, model_cls.UNIT_SPECS, backend, fcfg,
                         eval_fn=eval_fn, store=store)
    return Simulation(server, clients, model_cls, ds, cfg.backend)


def build_simulation(config: SimulationConfig) -> Simulation:
    """Build from a SimulationConfig. The legacy
    ``build_simulation("femnist", n_clients=..., method=...)`` kwargs shape
    was removed after its PR-2 deprecation cycle — construct a
    SimulationConfig (cohort fields go in CohortConfig)."""
    if not isinstance(config, SimulationConfig):
        raise TypeError(
            f"build_simulation takes a SimulationConfig, got "
            f"{type(config).__name__}; the legacy workload-name + kwargs "
            f"form was removed — use build_simulation(SimulationConfig("
            f"workload=..., cohort=CohortConfig(...)))")
    return _build(config)


def run_experiment(config: SimulationConfig, rounds: int,
                   eval_every: Optional[int] = None):
    """Driver: build + run a SimulationConfig for `rounds` rounds."""
    if eval_every is None:
        eval_every = max(1, rounds // 5)
    sim = build_simulation(config)
    hist = sim.server.run(rounds, eval_every=eval_every)
    return sim, hist
