"""Emulated FL clients.

Each client owns a non-IID data shard and a *speed model* calibrated to the
paper's measurement (App. A.3): end-to-end round time is linear in sub-model
size r, with multiplicative noise, plus a communication term proportional to
the transferred parameter count. Local training itself is real JAX SGD — the
deltas are genuine; only wall-clock is modeled (DESIGN.md §7.1). A client's
speed can be changed mid-run to emulate runtime variation (paper Fig. 4b).

Two execution paths share the same data/speed model:
  * SimClient.train — the sequential reference: one jit call per client,
    stragglers get a physically extracted sub-model (core/submodel.extract).
  * FleetClient — the batched path: exposes the epoch batch order and the
    time model so fl/fleet.py can run a whole cohort as one vmapped
    program. Both consume the per-client RNG in the same order
    (local_epochs permutations, then one noise draw), so a fleet round is
    bit-identical to the sequential round in everything but float summation
    order.
"""
from __future__ import annotations

import math

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import ClientUpdate

_JIT_CACHE: Dict[str, callable] = {}


def make_loss(model_cls):
    """Mean softmax cross-entropy — shared by the sequential and fleet paths."""
    def loss(params, xb, yb):
        logits = model_cls.apply(params, xb)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    return loss


def make_weighted_loss(model_cls):
    """Sample-weighted mean cross-entropy (fl/fleet.py batch padding).

    With weights 1 on a client's real samples and 0 on padding, this equals
    the client's own `mean` loss exactly, so cohorts whose shards are
    smaller than the global batch size still match the sequential path. An
    all-zero weight row (a padded *step*) yields a constant 0 loss, hence a
    zero gradient — a no-op SGD step."""
    def loss(params, xb, yb, wb):
        logits = model_cls.apply(params, xb)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
        return jnp.sum(wb * (lse - gold)) / jnp.maximum(wb.sum(), 1.0)
    return loss


def make_weighted_kernel_loss(model_cls, interpret: bool = True):
    """make_weighted_loss routed through the model's Pallas path.

    Identical weighted cross-entropy, but the forward is
    `model_cls.apply_kernels(params, xb, kmasks)` — the masked-matmul route
    of models/kernel_models.py, where dropped 128-blocks/heads are skipped
    rather than multiplied by zero (DESIGN.md §10). `kmasks` is the small
    per-group mask dict from `model_cls.kernel_masks`."""
    def loss(params, xb, yb, wb, kmasks):
        logits = model_cls.apply_kernels(params, xb, kmasks,
                                         interpret=interpret)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
        return jnp.sum(wb * (lse - gold)) / jnp.maximum(wb.sum(), 1.0)
    return loss


def _train_fn(model_cls):
    key = model_cls.__name__
    if key not in _JIT_CACHE:
        loss = make_loss(model_cls)

        @jax.jit
        def run(params, xs, ys, lr):
            """xs: (nb, bs, ...) — one pass of minibatch SGD."""
            def step(p, batch):
                xb, yb = batch
                g = jax.grad(loss)(p, xb, yb)
                return jax.tree.map(lambda w, gw: w - lr * gw, p, g), 0
            params, _ = jax.lax.scan(step, params, (xs, ys))
            return params
        _JIT_CACHE[key] = run
    return _JIT_CACHE[key]


@dataclass
class SimClient:
    id: int
    model_cls: type
    x: np.ndarray
    y: np.ndarray
    speed: float                     # seconds per epoch at r = 1.0
    comm_s_per_mparam: float = 0.05  # transfer seconds per 1e6 params (x2)
    noise: float = 0.03
    tail_sigma: float = 0.0          # lognormal heavy-tail sigma (0 = off)
    batch_size: int = 20
    local_epochs: int = 1
    lr: float = 0.01
    seed: int = 0
    _rng: np.random.RandomState = field(init=False, repr=False)

    def __post_init__(self):
        # modulo keeps the derived seed in RandomState's [0, 2**32) domain:
        # capacity pads (fl/async_rounds.py) carry reserved negative ids,
        # and in-range values pass through unchanged, so every pre-existing
        # client stream is preserved bit-for-bit
        self._rng = np.random.RandomState((self.seed + 1000 * self.id)
                                          % (2 ** 32))

    @property
    def n_samples(self) -> int:
        return len(self.y)

    @property
    def eff_batch_size(self) -> int:
        return min(self.batch_size, self.n_samples)

    # ------------------------------------------------------------ speed model
    def _epoch_order(self) -> np.ndarray:
        """One epoch's minibatch sample order (consumes one RNG draw)."""
        bs = self.eff_batch_size
        nb = self.n_samples // bs
        return self._rng.permutation(self.n_samples)[:nb * bs]

    def _sim_time(self, rate: float, n_params: int) -> float:
        """End-to-end emulated seconds (consumes one RNG draw; a second
        when tail_sigma > 0): linear in sub-model size + transfer term
        (paper App. A.3). `tail_sigma` multiplies the compute time by a
        lognormal draw — the heavy-tailed straggler latencies of the async
        benchmark. It lives here, not in the async ArrivalModel, so the
        synchronous barrier baseline experiences the identical latency
        distribution; at 0.0 no extra draw is consumed, preserving every
        pre-existing seeded run bit-for-bit."""
        sim = (self.speed * self.local_epochs * rate
               * (1.0 + self.noise * self._rng.randn()))
        if self.tail_sigma > 0.0:
            sim *= math.exp(self.tail_sigma * float(self._rng.randn()))
        sim += 2 * self.comm_s_per_mparam * n_params / 1e6
        return max(sim, 1e-6)

    # ------------------------------------------------------------------ train
    def train(self, params, keep_map=None, rate: float = 1.0) -> ClientUpdate:
        import time
        t0 = time.perf_counter()
        run = _train_fn(self.model_cls)
        bs = self.eff_batch_size
        nb = self.n_samples // bs
        new_params = params
        for _ in range(self.local_epochs):
            order = self._epoch_order()
            xs = jnp.asarray(self.x[order].reshape(nb, bs, *self.x.shape[1:]))
            ys = jnp.asarray(self.y[order].reshape(nb, bs))
            new_params = run(new_params, xs, ys, self.lr)
        delta = jax.tree.map(lambda a, b: a - b, new_params, params)
        real = time.perf_counter() - t0
        n_par = sum(x.size for x in jax.tree.leaves(params))
        sim = self._sim_time(rate, n_par)
        return ClientUpdate(delta, self.n_samples, None, sim, real, self.id)

    def evaluate(self, params, x=None, y=None):
        x = self.x if x is None else x
        y = self.y if y is None else y
        logits = self.model_cls.apply(params, jnp.asarray(x))
        return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())


@dataclass
class FleetClient(SimClient):
    """Batched-path client: same shard, speed model, and RNG stream as
    SimClient, but training happens inside fl/fleet.py's single vmapped
    cohort program instead of a per-client `train` call."""

    def local_batches(self):
        """(xs, ys) for one round: (local_epochs * nb, bs, ...) numpy arrays,
        consuming the RNG exactly like sequential train()."""
        bs = self.eff_batch_size
        nb = self.n_samples // bs
        orders = np.concatenate([self._epoch_order()
                                 for _ in range(self.local_epochs)])
        xs = self.x[orders].reshape(self.local_epochs * nb, bs,
                                    *self.x.shape[1:])
        ys = self.y[orders].reshape(self.local_epochs * nb, bs)
        return xs, ys

    def draw_sim_time(self, rate: float, n_params: int) -> float:
        """The post-training noise draw, in SimClient.train's RNG order."""
        return self._sim_time(rate, n_params)
