"""Vectorized client-fleet execution engine.

The sequential reference path (`FluidServer` + `SimClient.train`) dispatches
one jit call per client and gives every straggler a physically smaller
sub-model — so each new dropout rate means a new set of array shapes and a
recompile, and round time scales with the Python loop, not the hardware.

This engine runs the *entire cohort* as one compiled program:

  * Sub-models become dense keep-masks (core/submodel.keep_mask) applied
    inside the batched train step — the masking idiom of
    kernels/masked_ffn.py lifted to whole param trees. forward(mask*params)
    equals forward(extract(params)) on the kept coordinates because every
    consumer weight of a dropped neuron is zeroed, so full-model clients and
    every dropout rate share ONE compiled shape; the mask is data, not
    shape.
  * Local SGD for all C clients is jax.vmap over a jax.lax.scan of
    minibatches. Shards of different sizes pad to the cohort-max step count
    and batch size; padding is neutralized by per-sample loss weights.
  * Hyperparameters are data too: learning rates are a vmapped (C,) array
    and per-client step counts ride on the same zero-weight padding that
    absorbs ragged shards, so heterogeneous (lr, local-epochs) cohorts —
    and the serving engine's per-request sub-models (launch/serving.py) —
    share one compiled program with the uniform case.
  * Gradients are mask-projected each step, so deltas come back already
    mask-zeroed in full coordinates — exactly what embed_delta() would have
    produced — and aggregation collapses to one fused device-side
    tree-reduce (core/aggregate.aggregate_stacked) instead of per-update
    Python arithmetic.
  * Masks are deduplicated into a (K, ...) bank (core/maskbank.MaskBank:
    all-ones row 0 + one row per straggler keep-map) indexed per client, so
    mask memory scales with the number of *distinct* sub-models, not the
    fleet size.

Numerical contract (tests/test_fleet.py): with the same seeds, a fleet
round reproduces the sequential round's deltas, sim-times, and aggregated
params up to float summation order — including cohorts with per-client
(lr, local-epochs).
"""
from __future__ import annotations

import functools

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import invariant as inv
from repro.core import submodel as sub
from repro.core.aggregate import ClientUpdate, aggregate_stacked
from repro.core.maskbank import MaskBank
from repro.fl.client import (FleetClient, make_weighted_kernel_loss,
                             make_weighted_loss)
from repro.kernels.ops import _default_interpret

_COHORT_CACHE: Dict[tuple, callable] = {}

# lax.scan under vmap is pathological on CPU for batched-weight train steps
# (measured ~6x slower than the identical unrolled program: the loop body
# blocks cross-step fusion and re-materializes the (C, ...) carry). Small
# step counts unroll fully in Python; longer ones use scan's unroll knob.
_FULL_UNROLL_STEPS = 16
_SCAN_UNROLL = 8


def _cohort_fn(model_cls, use_kernels: bool = False,
               interpret: bool = True):
    """One compiled program: vmapped masked local SGD for a whole cohort.

    use_kernels routes every forward/backward through the model's
    `apply_kernels` Pallas path (models/kernel_models.py): dropped
    128-blocks/heads are *skipped* via the custom_vjp kernels of
    DESIGN.md §10 instead of multiplied by zero, so a rate-r straggler does
    ~r of the FLOPs. Numerically equivalent to the dense path (the skipped
    activations are act(0) = 0 and the skipped dW tiles are exact zeros) —
    enforced by tests/test_kernel_grad.py."""
    key = (model_cls.__name__, use_kernels, interpret)
    if key not in _COHORT_CACHE:
        if use_kernels:
            kloss = make_weighted_kernel_loss(model_cls, interpret=interpret)
        loss = make_weighted_loss(model_cls)

        @functools.partial(jax.jit, static_argnames=("n_steps",))
        def run(params, mask_bank, mask_idx, xs, ys, sw, lrs, n_steps):
            """params: full tree (broadcast); mask_bank: (K, ...) leaves;
            mask_idx: (C,); xs: (C, S, bs, ...); ys: (C, S, bs);
            sw: (C, S, bs) per-sample weights — 1.0 on real samples, 0.0 on
            batch/step padding (an all-zero step is a no-op);
            lrs: (C,) per-client learning rates (hyperparameters are data —
            heterogeneous cohorts don't re-specialize the program).
            Returns mask-zeroed full-coordinate deltas, (C, ...) leaves."""
            def one_client(mi, x, y, v, lr):
                m = jax.tree.map(lambda b: b[mi], mask_bank)
                w0 = sub.apply_mask(params, m)
                if use_kernels:
                    kmasks = model_cls.kernel_masks(m)

                def step(w, batch):
                    xb, yb, vb = batch
                    if use_kernels:
                        g = jax.grad(kloss)(w, xb, yb, vb, kmasks)
                    else:
                        g = jax.grad(loss)(w, xb, yb, vb)
                    return jax.tree.map(
                        lambda a, ga, ma: a - lr * ma * ga,
                        w, g, m), 0
                if n_steps <= _FULL_UNROLL_STEPS:
                    w = w0
                    for s in range(n_steps):
                        w, _ = step(w, (x[s], y[s], v[s]))
                else:
                    w, _ = jax.lax.scan(step, w0, (x, y, v),
                                        unroll=_SCAN_UNROLL)
                # every update step carried the mask factor => pre-zeroed
                return jax.tree.map(lambda a, b: a - b, w, w0)
            return jax.vmap(one_client)(mask_idx, xs, ys, sw, lrs)
        _COHORT_CACHE[key] = run
    return _COHORT_CACHE[key]


@dataclass
class CohortResult:
    """Stacked outputs of one fleet round + lazy per-client views."""
    engine: "FleetEngine"
    deltas: dict                    # tree of (C, ...) leaves, mask-zeroed
    weights: jnp.ndarray            # (C,) sample counts
    mask_bank: dict                 # tree of (K, ...) leaves
    mask_idx: jnp.ndarray           # (C,) int32
    client_ids: List[int]
    sim_times: Dict[int, float]
    straggler_ids: frozenset
    members: Optional[np.ndarray] = None   # (C,) bool; None = all real

    def _is_member(self, i: int) -> bool:
        return self.members is None or bool(self.members[i])

    def aggregate(self, global_params):
        """Fused device-side masked FedAvg (== core.aggregate.aggregate).
        Padding slots (members[i] == False) carry zero weight AND zero
        deltas (their step count is 0), so they cancel out of both the
        numerator and the per-mask denominator."""
        return aggregate_stacked(global_params, self.deltas, self.weights,
                                 self.mask_bank, self.mask_idx)

    def non_straggler_stats(self, prev_params) -> List[Dict[str, np.ndarray]]:
        """Per-client invariant-neuron stats, computed batched on device."""
        sel = np.array([i for i, cid in enumerate(self.client_ids)
                        if cid not in self.straggler_ids
                        and self._is_member(i)], dtype=np.int32)
        if sel.size == 0:
            return []
        picked = jax.tree.map(lambda d: d[sel], self.deltas)
        stacked = self.engine._stats_fn(prev_params, picked)
        return [{g: np.asarray(v[i]) for g, v in stacked.items()}
                for i in range(sel.size)]

    def updates(self) -> List[ClientUpdate]:
        """Materialize sequential-style ClientUpdates (tests / inspection)."""
        out = []
        for i, cid in enumerate(self.client_ids):
            if not self._is_member(i):
                continue
            delta = jax.tree.map(lambda d: d[i], self.deltas)
            mask = None
            if cid in self.straggler_ids:
                row = int(self.mask_idx[i])
                mask = jax.tree.map(lambda b: b[row], self.mask_bank)
            out.append(ClientUpdate(delta, int(self.weights[i]), mask,
                                    self.sim_times[cid], 0.0, cid))
        return out


class FleetEngine:
    """Runs a homogeneous-model client fleet as single vmapped programs.

    The model architecture is uniform across the cohort (one param tree
    shape); per-client hyperparameters (lr, local epochs / step counts) and
    per-client sub-model masks are vmapped data, not program structure.
    """

    def __init__(self, model_cls, clients: Sequence[FleetClient], unit_specs,
                 use_kernels: bool = False):
        self.model_cls = model_cls
        self.clients = list(clients)
        self.unit_specs = unit_specs
        self.use_kernels = bool(use_kernels)
        if not self.clients:
            raise ValueError("FleetEngine needs at least one client")
        if self.use_kernels and not hasattr(model_cls, "apply_kernels"):
            raise ValueError(
                f"use_kernels=True needs a model exposing apply_kernels / "
                f"kernel_masks (see models/kernel_models.py); "
                f"{model_cls.__name__} does not")
        # batch dim pads to the cohort max; smaller shards get sample weights
        self.bs = max(c.eff_batch_size for c in self.clients)
        self.client_steps = np.array(
            [c.local_epochs * (c.n_samples // c.eff_batch_size)
             for c in self.clients], np.int32)
        self.steps = int(self.client_steps.max())
        self.lrs = np.array([c.lr for c in self.clients], np.float32)
        self._run = _cohort_fn(model_cls, self.use_kernels,
                               interpret=_default_interpret())
        self._ones_mask: Optional[dict] = None
        self._stats_jit = None
        self._bank_cache = None        # (fingerprint, bank, idx, n_by_row)

    # ------------------------------------------------------------- internals
    def _stats_fn(self, prev, stacked_deltas):
        if self._stats_jit is None:
            specs = self.unit_specs

            def one(prev_p, d):
                new = jax.tree.map(lambda a, b: a + b, prev_p, d)
                return inv.neuron_stats(prev_p, new, specs)
            self._stats_jit = jax.jit(
                lambda p, ds: jax.vmap(lambda d: one(p, d))(ds))
        return self._stats_jit(prev, stacked_deltas)

    def _stacked_data(self, n_steps: Optional[np.ndarray] = None):
        """(xs, ys, sw): per-client epoch batches padded to (steps, bs);
        sw is 1.0 on real samples, 0.0 on batch/step padding. Consumes each
        client's RNG exactly like SimClient.train. n_steps (C,) caps the
        number of *real* SGD steps per client by zero-weighting the tail —
        step counts are data riding on the same padding as ragged shards.

        Rebuilt host-side every round (only the permutations change); at
        paper scales this is <2% of the cohort program's runtime. If fleets
        outgrow that, stage shards on device once and gather by permutation
        indices instead."""
        C = len(self.clients)
        feat = self.clients[0].x.shape[1:]
        xs = np.zeros((C, self.steps, self.bs, *feat),
                      self.clients[0].x.dtype)
        ys = np.zeros((C, self.steps, self.bs), np.int32)
        sw = np.zeros((C, self.steps, self.bs), np.float32)
        for i, c in enumerate(self.clients):
            x, y = c.local_batches()
            s, b = x.shape[0], x.shape[1]
            xs[i, :s, :b] = x
            ys[i, :s, :b] = y
            sw[i, :s, :b] = 1.0
            if n_steps is not None:
                sw[i, int(n_steps[i]):] = 0.0
        return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(sw)

    def _mask_bank(self, params, keep_maps: Dict[int, dict]):
        """(bank, idx, n_params_by_row): all-ones row 0 + one row per
        *distinct* straggler keep-map (core/maskbank.MaskBank dedupe); idx
        maps client position -> bank row. Cached across rounds while the
        keep-maps are unchanged (they only move on calibration steps)."""
        km_fp = {cid: tuple((g, kept.tobytes())
                            for g, kept in sorted(km.items()))
                 for cid, km in keep_maps.items()}
        fp = tuple(sorted(km_fp.items()))
        if self._bank_cache is not None and self._bank_cache[0] == fp:
            return self._bank_cache[1:]
        if self._ones_mask is None:
            self._ones_mask = jax.tree.map(
                lambda p: jnp.ones(p.shape, jnp.float32), params)
        bank_obj = MaskBank(self._ones_mask)
        row_of = {cid: bank_obj.row_for(
            km_fp[cid],
            functools.partial(sub.keep_mask, params, self.unit_specs,
                              keep_maps[cid]))
            for cid in sorted(keep_maps)}
        bank = bank_obj.stacked()
        idx = jnp.asarray([row_of.get(c.id, 0) for c in self.clients],
                          jnp.int32)
        # exact integer param counts per row (per-leaf int32 sums of a 0/1
        # mask cannot overflow; accumulate in host int64 across leaves)
        n_by_row = sum(
            np.asarray(b.sum(axis=tuple(range(1, b.ndim)),
                             dtype=jnp.int32)).astype(np.int64)
            for b in jax.tree.leaves(bank))
        self._bank_cache = (fp, bank, idx, n_by_row)
        return bank, idx, n_by_row

    def _execute(self, params, bank, idx, xs, ys, sw, lrs, weights):
        """Run the cohort program. Returns (deltas, extra): extra is None
        here; the sharded subclass (fl/shard_fleet.py) returns the
        hierarchically-reduced aggregation partials instead of recomputing
        them from the gathered deltas."""
        return self._run(params, bank, idx, xs, ys, sw, lrs,
                         self.steps), None

    def _wrap_result(self, extra, **kw) -> "CohortResult":
        return CohortResult(**kw)

    # ------------------------------------------------------------------- API
    def run_cohort(self, params, keep_maps: Dict[int, dict],
                   rates: Optional[Dict[int, float]] = None,
                   lr=None, n_steps=None, members=None) -> CohortResult:
        """One FL round for the whole fleet: keep_maps/rates per straggler
        client id (absent => full model).

        lr: optional scalar or (C,) array overriding the clients' own
        learning rates; n_steps: optional (C,) int array capping each
        client's real SGD steps. Both are vmapped data — heterogeneous
        values reuse the same compiled program as the uniform cohort.

        members: optional (C,) bool marking which slots are real clients —
        partial-cohort execution for callers that must keep the program
        shape capacity-padded while dispatching fewer than C clients
        (fl/async_rounds.py pads every dispatch group to buffer_k). A
        padding slot runs 0 SGD steps (all its sample weights are zero, so
        its delta is exactly zero), carries zero aggregation weight, draws
        no sim time (its RNG stream is never touched), and is excluded
        from stats and updates()."""
        rates = rates or {}
        C = len(self.clients)
        if lr is None:
            lrs = self.lrs
        else:
            lrs = np.broadcast_to(np.asarray(lr, np.float32), (C,))
        if n_steps is not None:
            n_steps = np.asarray(n_steps, np.int32)
            if n_steps.shape != (C,):
                raise ValueError(f"n_steps must be ({C},), "
                                 f"got {n_steps.shape}")
        if members is not None:
            members = np.asarray(members, bool)
            if members.shape != (C,):
                raise ValueError(f"members must be ({C},), "
                                 f"got {members.shape}")
            base_steps = self.client_steps if n_steps is None else n_steps
            n_steps = np.where(members, base_steps, 0).astype(np.int32)
        xs, ys, sw = self._stacked_data(n_steps)
        bank, idx, n_by_row = self._mask_bank(params, keep_maps)
        w_host = np.asarray([c.n_samples for c in self.clients], np.float32)
        if members is not None:
            w_host = np.where(members, w_host, 0.0).astype(np.float32)
        weights = jnp.asarray(w_host)
        deltas, extra = self._execute(params, bank, idx, xs, ys, sw,
                                      jnp.asarray(lrs), weights)
        idx_host = np.asarray(idx)
        sim_times = {
            c.id: c.draw_sim_time(rates.get(c.id, 1.0),
                                  int(n_by_row[idx_host[i]]))
            for i, c in enumerate(self.clients)
            if members is None or members[i]}
        return self._wrap_result(
            extra, engine=self, deltas=deltas, weights=weights,
            mask_bank=bank, mask_idx=idx,
            client_ids=[c.id for c in self.clients], sim_times=sim_times,
            straggler_ids=frozenset(keep_maps), members=members)
