"""MiniCPM3-4B — MLA attention [hf:openbmb/MiniCPM3-4B].

62 layers, d_model=2560, 40 heads, MLA kv_lora_rank=256, d_ff=6400 (SwiGLU),
vocab 73448.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    citation="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    ffn_kind="swiglu",
    use_mla=True,
    kv_lora_rank=256,
    q_lora_rank=768,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
    vocab_size=73448,
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
