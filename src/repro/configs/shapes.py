"""The four assigned input shapes + ShapeDtypeStruct input specs.

``input_specs(cfg, shape)`` returns the exact kwargs pytree that the
corresponding step function is lowered with — weak-type-correct, shardable,
and allocation-free.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def window_override_for(cfg: ModelConfig, shape: InputShape):
    """long_500k swaps full attention for the sliding-window variant."""
    if shape.name != "long_500k":
        return None
    has_full_attn = any(k == "attn" for k in cfg.block_pattern) or cfg.is_encdec
    return cfg.long_context_window if has_full_attn else None


def input_specs(cfg: ModelConfig, shape, batch_override=None):
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)

    if shape.mode == "train":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec:
            spec["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        return {"batch": spec}

    if shape.mode == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec:
            spec["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        return {"batch": spec}

    # decode: ONE new token against a seq_len-deep cache
    from repro.models import model as model_lib
    wo = window_override_for(cfg, shape)
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32),
            "caches": model_lib.cache_specs(cfg, B, S, window_override=wo)}
