"""DeepSeek-V2-Lite 16B — MLA + fine-grained MoE [arXiv:2405.04434].

27 layers, d_model=2048, 16 heads, MLA kv_lora_rank=512, MoE with
2 shared + 64 routed experts top-6, expert d_ff=1408; first layer dense.
(The assignment line lists both "64e top-6" and "160 routed"; 160 routed
belongs to full V2 — the Lite card is 64 routed, which we use.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    citation="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,            # dense FFN of the first layer
    moe_d_ff=1408,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_k_dense=1,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,         # Lite has no q-LoRA
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    vocab_size=102400,
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
