"""Granite-20B code model [arXiv:2405.04324].

52 layers, d_model=6144, 48 heads MQA (kv=1), d_ff=24576 (non-gated GELU),
vocab 49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    arch_type="dense",
    citation="arXiv:2405.04324",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    ffn_kind="gelu",
    use_bias=True,
    norm_kind="layernorm",
    vocab_size=49152,
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
