from repro.configs.base import ModelConfig, get_config, all_configs, ARCH_IDS
from repro.configs.shapes import INPUT_SHAPES, input_specs

__all__ = ["ModelConfig", "get_config", "all_configs", "ARCH_IDS",
           "INPUT_SHAPES", "input_specs"]
