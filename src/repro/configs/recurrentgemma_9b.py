"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention 2:1 [arXiv:2402.19427].

38 layers in a (recurrent, recurrent, local_attn) cycle (12 full cycles + 2
trailing recurrent layers), d_model=4096, 16 heads MQA (kv=1), d_ff=12288
(gated GeLU), vocab 256000, local attention window 2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    citation="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    ffn_kind="gelu_gated",
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    lru_width=4096,
    conv1d_width=4,
    logit_softcap=30.0,
    remat="block",
    optimizer="adamw",
)
