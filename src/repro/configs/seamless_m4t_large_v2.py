"""SeamlessM4T-Large v2 text/speech backbone [arXiv:2308.11596].

Enc-dec transformer: 24 encoder + 24 decoder layers ("24L" in the assignment
is read as the per-stack depth of the published large-v2 card), d_model=1024,
16 heads (GQA kv=16 == MHA), d_ff=8192 (ReLU, non-gated), vocab 256206.
The speech frontend (mel + conformer feature extractor) is a stub: input_specs
provides frame embeddings (B, S, d_model) directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    citation="arXiv:2308.11596",
    n_layers=24,
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    ffn_kind="relu",
    norm_kind="layernorm",
    use_bias=True,
    vocab_size=256206,
    frontend="audio",
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
