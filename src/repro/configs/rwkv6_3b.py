"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay [arXiv:2404.05892].

32 layers, d_model=2560 (40 heads x 64), channel-mix d_ff=8960 (squared-ReLU),
vocab 65536. Trained/served via a chunked linear-attention formulation
(intra-chunk parallel, inter-chunk scan) for TPU efficiency.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    citation="arXiv:2404.05892",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # = d_model / rwkv_head_size
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    ffn_kind="relu2",
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_size=64,
    rwkv_chunk=128,   # §Perf hillclimb-2 optimum (sweep 16/32/64/128/256)
    remat="block",
    optimizer="adamw",
)
