"""Chameleon-34B — early-fusion mixed-modal [arXiv:2405.09818].

48 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=22016 (SwiGLU),
vocab 65536 including VQ-VAE image-token codes. Early fusion means the
"vision frontend" is the VQ tokenizer — inputs are already token ids, so
input_specs supplies interleaved text+image token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    citation="arXiv:2405.09818",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    vocab_size=65536,
    frontend="vision",
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
