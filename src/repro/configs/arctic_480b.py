"""Snowflake Arctic 480B — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35 layers, d_model=7168, 56 heads (GQA kv=8), MoE 128 experts top-2 with
expert d_ff=4864, plus a dense residual FFN (d_ff=4864) in parallel,
vocab 32000. Optimizer sgdm to bound per-chip optimizer-state bytes at 480B.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    citation="hf:Snowflake/snowflake-arctic-base",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,             # dense residual branch
    moe_d_ff=4864,
    n_experts=128,
    top_k=2,
    moe_weight_stream=True,
    grad_accum=8,
    dense_ff_residual=True,
    vocab_size=32000,
    block_pattern=("attn",),
    param_dtype="bfloat16",  # 480B: fp32 master + state would exceed HBM
    optimizer="sgdm",
    remat="block",
)
