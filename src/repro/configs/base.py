"""Model configuration schema + registry.

Every assigned architecture registers a ``ModelConfig`` here; the launcher,
dry-run, smoke tests and FL integration all consume the same object.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------------
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    citation: str = ""

    # trunk ------------------------------------------------------------------
    n_layers: int = 2          # decoder layers (encdec: decoder side)
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    vocab_pad_multiple: int = 256   # pad vocab so "model"-axis sharding divides

    # block layout -----------------------------------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)  # cycled layer kinds
    ffn_kind: str = "swiglu"   # swiglu | gelu | relu | relu2
    use_bias: bool = False
    parallel_block: bool = False   # command-r style parallel attn+ffn
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # attention --------------------------------------------------------------
    window: Optional[int] = None        # sliding window for "local" layers
    long_context_window: int = 4096     # window substituted at long_500k

    # MLA --------------------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # MoE --------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_ff_residual: bool = False   # Arctic: dense FFN in parallel w/ MoE
    first_k_dense: int = 0            # DeepSeek: first k layers use dense FFN
    router_aux_coef: float = 0.001
    moe_impl: str = "capacity"        # capacity | ragged (ragged_dot on TPU)
    moe_token_chunk: int = 8192       # scan+remat over token chunks
    moe_expert_chunk: int = 0         # experts per scan chunk (0 = all at once)
    moe_weight_stream: bool = False   # stream expert chunks over the data axis
    moe_capacity_factor: float = 1.25  # raise when expert-dropping concentrates load

    # RWKV-6 -----------------------------------------------------------------
    rwkv_head_size: int = 64
    rwkv_chunk: int = 256
    rwkv_chunk_dtype: str = "float32"  # decay-tensor einsum dtype (bf16 = half the traffic)

    # RG-LRU (RecurrentGemma) --------------------------------------------------
    lru_width: int = 0                # defaults to d_model
    conv1d_width: int = 4

    # encoder–decoder ----------------------------------------------------------
    enc_layers: int = 0               # >0 => enc-dec model
    cross_every: int = 1              # cross-attn in every decoder layer

    # modality frontend stub ---------------------------------------------------
    frontend: Optional[str] = None    # None | "audio" | "vision"

    # numerics -----------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # training -----------------------------------------------------------------
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    remat: str = "none"               # none | block  (activation checkpointing)
    grad_accum: int = 1               # microbatch count (gradient accumulation)

    # derived -------------------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return all(k != "attn" and k != "local_attn" for k in self.block_pattern)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind list of length n_layers (decoder side)."""
        pat = self.block_pattern
        kinds = []
        for i in range(self.n_layers):
            k = pat[i % len(pat)]
            if self.n_experts and k == "attn":
                k = "attn"  # MoE-ness is carried by the ffn field, see segments
            kinds.append(k)
        return tuple(kinds)

    def ffn_kind_for_layer(self, i: int) -> str:
        """'dense' or 'moe' FFN for decoder layer i."""
        if self.n_experts and i >= self.first_k_dense:
            return "moe"
        return "dense"

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant for CPU smoke tests -------------------------------------
    def smoke(self) -> "ModelConfig":
        d = min(self.d_model, 256)
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = min(self.head_dim, 32)
        over = dict(
            n_layers=min(self.n_layers, 2) if not self.block_pattern or len(self.block_pattern) == 1
            else len(self.block_pattern),
            d_model=d, n_heads=heads, n_kv_heads=kv, head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512), vocab_pad_multiple=64,
            window=None if self.window is None else min(self.window, 64),
        )
        if self.n_experts:
            over.update(n_experts=min(self.n_experts, 4),
                        top_k=min(self.top_k, 2),
                        moe_d_ff=min(self.moe_ff, 128),
                        n_shared_experts=min(self.n_shared_experts, 1),
                        first_k_dense=min(self.first_k_dense, 1))
        if self.use_mla:
            over.update(kv_lora_rank=min(self.kv_lora_rank, 64), q_lora_rank=0,
                        qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32)
        if self.arch_type == "ssm":
            over.update(rwkv_head_size=32, rwkv_chunk=16, d_model=128, d_ff=448)
        if self.lru_width:
            over.update(lru_width=128, d_model=128)
        if self.enc_layers:
            over.update(enc_layers=2, n_layers=2)
        return self.with_overrides(**over)


# ---------------------------------------------------------------------------
# Registry

_ARCH_MODULES = [
    "seamless_m4t_large_v2",
    "rwkv6_3b",
    "deepseek_v2_lite_16b",
    "granite_20b",
    "stablelm_12b",
    "minicpm3_4b",
    "recurrentgemma_9b",
    "command_r_35b",
    "arctic_480b",
    "chameleon_34b",
]

ARCH_IDS = [m.replace("_", "-") for m in _ARCH_MODULES]

_REGISTRY: dict = {}


def get_config(arch_id: str) -> ModelConfig:
    """Look up an architecture config by its public id (e.g. 'rwkv6-3b')."""
    key = arch_id.replace("-", "_")
    if key not in _REGISTRY:
        mod = importlib.import_module(f"repro.configs.{key}")
        _REGISTRY[key] = mod.CONFIG
    return _REGISTRY[key]


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
