"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

40 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=22528 (SwiGLU), no biases,
parallel attention+FFN blocks, vocab 256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    citation="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    ffn_kind="swiglu",
    use_bias=False,
    parallel_block=True,
    norm_kind="layernorm",
    vocab_size=256000,
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
