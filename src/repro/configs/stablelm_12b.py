"""StableLM-2 12B [hf:stabilityai/stablelm-2-1_6b family].

40 layers, d_model=5120, 32 heads (GQA kv=8), d_ff=13824 (SwiGLU),
vocab 100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    citation="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    ffn_kind="swiglu",
    vocab_size=100352,
    block_pattern=("attn",),
    remat="block",
    optimizer="adamw",
)
