"""Client data partitioning: writer-based non-IID (LEAF style) and IID."""
from __future__ import annotations

import numpy as np


def partition_non_iid(ds, n_clients: int, seed: int = 0):
    """Group examples by writer/role, assign writers to clients (LEAF style)."""
    rng = np.random.RandomState(seed)
    writers = np.unique(ds.writer)
    rng.shuffle(writers)
    buckets = [[] for _ in range(n_clients)]
    for i, w in enumerate(writers):
        buckets[i % n_clients].append(w)
    out = []
    for ws in buckets:
        idx = np.where(np.isin(ds.writer, ws))[0]
        rng.shuffle(idx)
        out.append(idx)
    return out


def partition_iid(ds, n_clients: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(ds.y))
    return np.array_split(idx, n_clients)
