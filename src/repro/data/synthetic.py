"""Deterministic synthetic stand-ins for FEMNIST / CIFAR10 / Shakespeare.

The container is offline, so we generate classification problems with real
learnable structure (class-conditional prototypes + noise; for the char-LM a
stochastic grammar with per-class transition matrices mirroring Shakespeare's
role-based non-IID split). Accuracy *orderings* between dropout methods are
the reproduction target, not absolute values (DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    name: str
    x: np.ndarray          # inputs
    y: np.ndarray          # int labels
    writer: np.ndarray     # non-IID partition key (writer/class role)
    num_classes: int
    x_test: np.ndarray
    y_test: np.ndarray


def _image_dataset(name, shape, num_classes, n, n_test, n_writers, seed):
    rng = np.random.RandomState(seed)
    protos = rng.randn(num_classes, *shape).astype(np.float32)
    # writer-specific style offsets make the partition genuinely non-IID
    styles = 0.6 * rng.randn(n_writers, *shape).astype(np.float32)

    def gen(m, with_writer=True):
        y = rng.randint(0, num_classes, size=m)
        w = rng.randint(0, n_writers, size=m)
        x = protos[y] + 1.2 * rng.randn(m, *shape).astype(np.float32)
        if with_writer:
            x = x + styles[w]
        return x, y, w
    x, y, w = gen(n)
    xt, yt, _ = gen(n_test)
    return Dataset(name, x, y, w, num_classes, xt, yt)


def _char_dataset(n, n_test, n_roles, seq_len, vocab, seed):
    rng = np.random.RandomState(seed)
    # per-role Markov transition matrices (roles ~ Shakespeare characters)
    base = rng.dirichlet(np.ones(vocab) * 0.1, size=vocab)
    seqs, labels, roles = [], [], []
    mats = []
    for r in range(n_roles):
        perm = rng.permutation(vocab)
        mats.append(base[perm][:, perm])

    def sample(m):
        xs = np.zeros((m, seq_len), np.int32)
        ys = np.zeros((m,), np.int32)
        ws = rng.randint(0, n_roles, size=m)
        for i in range(m):
            T = mats[ws[i]]
            c = rng.randint(vocab)
            for t in range(seq_len):
                xs[i, t] = c
                c = rng.choice(vocab, p=T[c])
            ys[i] = c
        return xs, ys, ws
    x, y, w = sample(n)
    xt, yt, _ = sample(n_test)
    return Dataset("shakespeare", x, y, w, vocab, xt, yt)


def make_dataset(name: str, n: int = 4000, n_test: int = 800,
                 n_partitions: int = 32, seed: int = 0) -> Dataset:
    if name == "femnist":
        return _image_dataset("femnist", (28, 28, 1), 62, n, n_test,
                              n_partitions, seed)
    if name == "cifar10":
        return _image_dataset("cifar10", (32, 32, 3), 10, n, n_test,
                              n_partitions, seed + 1)
    if name == "shakespeare":
        return _char_dataset(n, n_test, n_partitions, seq_len=20, vocab=80,
                             seed=seed + 2)
    if name == "synth":
        # flat 32-dim vectors: the population-scale probe workload — same
        # prototype+style generator, just without image structure
        return _image_dataset("synth", (32,), 10, n, n_test,
                              n_partitions, seed + 3)
    raise ValueError(name)


DATASETS = ("femnist", "cifar10", "shakespeare", "synth")
