from repro.data.synthetic import make_dataset, DATASETS
from repro.data.partition import partition_non_iid, partition_iid
