"""Quickstart: FLuID end-to-end in ~a minute on CPU.

Builds a 5-client federated simulation on synthetic FEMNIST with one
straggler, runs a few rounds of Invariant-Dropout FLuID, and prints the
straggler's round time converging to the next-slowest client (paper Fig 4a)
plus the growing invariant-neuron fraction (paper Fig 6).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.fl import CohortConfig, SimulationConfig, build_simulation

sim = build_simulation(SimulationConfig(
    workload="femnist",
    policy="invariant",
    cohort=CohortConfig(
        n_clients=5,
        straggler_ids=(0,),  # client 0 is ~30% slower (paper Fig 2a regime)
        n_data=600,
    ),
))

print(f"{'round':>5} {'round_time':>10} {'straggler':>9} {'target':>7} "
      f"{'r':>5} {'th':>8} {'inv%':>5} {'acc':>5}")
for i in range(8):
    h = sim.server.run_round(eval_now=(i % 4 == 3))
    r = h.rates.get(0, 1.0) if h.rates else 1.0
    print(f"{h.round:>5} {h.round_time:>10.2f} {h.straggler_time:>9.2f} "
          f"{h.t_target:>7.2f} {r:>5.2f} {h.threshold:>8.5f} "
          f"{h.invariant_frac:>5.2f} {h.accuracy:>5.2f}")

print("\nThe straggler now trains a sub-model sized ~1/speedup; its round "
      "time matches the next-slowest client within ~10% (paper Fig 4a).")
