"""Paper Table 2 (small scale): Random vs Ordered vs Invariant Dropout.

Trains the same federated workload with each dropout policy at a fixed
sub-model size and prints final test accuracy. Invariant Dropout picks the
neurons whose updates stay below the calibrated threshold for the majority
of non-straggler clients — the paper's core claim is that this ordering
(Invariant >= Ordered >= Random) holds across sizes.

Run:  PYTHONPATH=src python examples/compare_dropout_methods.py [rounds]
"""
import sys

from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation)

rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 15
rate = 0.75

print(f"sub-model size r={rate}, {rounds} rounds, 5 clients, 1 straggler")
for method in ("random", "ordered", "invariant"):
    sim = build_simulation(SimulationConfig(
        workload="femnist", policy=method, fixed_rate=rate, seed=0,
        cohort=CohortConfig(n_clients=5, straggler_ids=(0,), n_data=1200)))
    hist = sim.server.run(rounds, eval_every=rounds)
    print(f"  {method:10s} final accuracy = {hist[-1].accuracy:.3f}")
