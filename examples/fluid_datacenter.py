"""FLuID at datacenter scale: masked sub-model training on a transformer.

The big-architecture integration (DESIGN.md §2): a straggler *pod* trains a
masked sub-model whose FFN units were invariant across the fast pods. One
compiled step serves every mask. Uses a reduced stablelm config on CPU; on
a real mesh the same code inherits the launch shardings.

Run:  PYTHONPATH=src python examples/fluid_datacenter.py
"""
from repro.configs import get_config
from repro.launch.train import run_fluid

cfg = get_config("stablelm-12b").smoke().with_overrides(grad_accum=1)
params, log = run_fluid(cfg, steps=12, batch=2, seq=32, rate=0.75,
                        calibrate_every=4)
full_t = sum(t for _, t, _ in log)
fluid_t = sum(t for _, _, t in log)
print(f"\nmodeled straggler-pod time: full={full_t:.1f}u "
      f"fluid={fluid_t:.1f}u ({full_t / fluid_t:.2f}x faster once masked)")
