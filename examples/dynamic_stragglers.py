"""Paper Fig 4b: FLuID re-adapts when the straggler changes at runtime.

Halfway through training, client 0 (the original straggler) recovers and
client 3 degrades (emulating a background process on the phone). FLuID's
per-epoch recalibration detects the change and re-targets the sub-model.

Run:  PYTHONPATH=src python examples/dynamic_stragglers.py
"""
from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation)

sim = build_simulation(SimulationConfig(
    workload="femnist", policy="invariant", seed=0,
    cohort=CohortConfig(n_clients=5, straggler_ids=(0,), n_data=500)))

print("phase 1: client 0 is the straggler")
for _ in range(4):
    h = sim.server.run_round()
    print(f"  round {h.round}: stragglers={h.stragglers} rates={h.rates}")

print("\n>>> runtime shift: client 0 recovers, client 3 degrades <<<\n")
sim.set_speed(0, 10.0)
sim.set_speed(3, 13.5)

print("phase 2: FLuID recalibrates")
for _ in range(4):
    h = sim.server.run_round()
    print(f"  round {h.round}: stragglers={h.stragglers} rates={h.rates}")

assert sim.server.plan.stragglers == [3], "recalibration failed"
print("\nFLuID now targets client 3 — dynamic adaptation works (Fig 4b).")
