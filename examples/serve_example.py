"""Personalized sub-model serving: one compiled decode step, many clients.

Queues requests carrying three different sub-model sizes (1.0 = full model,
0.5, 0.25) with ragged prompt/generation lengths through the
continuous-batching ServeEngine. All of them share ONE compiled decode
chunk — the trace counts printed at the end stay at 1 no matter how the
rates are mixed. Works for every decoder-only architecture (GQA ring cache,
MLA latent cache, RWKV/RG-LRU state — recurrent archs need full-window
prompts):

  PYTHONPATH=src python examples/serve_example.py stablelm-12b

The pre-engine synchronous path survives as
``python -m repro.launch.serve --baseline``.
"""
import sys

import numpy as np

from repro.configs import get_config
from repro.launch.serving import ServeEngine, ServeRequest, rate_masks
from repro.models import model as model_lib

import jax

arch = sys.argv[1] if len(sys.argv) > 1 else "stablelm-12b"
cfg = get_config(arch).smoke()
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

eng = ServeEngine(cfg, params, batch_size=2, max_prompt_len=12,
                  max_gen_len=12)
rng = np.random.RandomState(0)
for i, r in enumerate([1.0, 0.5, 0.25, 0.5, 1.0]):
    L = eng.max_prompt_len if eng.recurrent else int(rng.randint(6, 13))
    prompt = rng.randint(0, min(cfg.vocab_size, 256), (L,), dtype=np.int32)
    masks = None if r >= 1.0 else rate_masks(cfg, r, seed=0)
    rid = eng.submit(ServeRequest(prompt, gen_len=int(rng.randint(6, 13)),
                                  masks=masks))
    print(f"request {rid}: sub-model r={r}, prompt {L} tokens")

results = eng.run()
for rid in sorted(results):
    print(f"request {rid} -> {results[rid].tolist()}")
s = eng.summary()
print(f"{arch}: {s['tok_per_s']:.0f} tok/s decode, "
      f"trace_counts={s['trace_counts']} (one compile serves every rate)")
