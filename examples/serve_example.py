"""Batched serving: prefill + greedy decode with arch-appropriate caches.

Works for every assigned architecture (GQA ring cache, MLA latent cache,
RWKV constant-size state, RG-LRU state + local window):

  PYTHONPATH=src python examples/serve_example.py rwkv6-3b
"""
import sys

from repro.configs import get_config
from repro.launch.serve import serve

arch = sys.argv[1] if len(sys.argv) > 1 else "stablelm-12b"
cfg = get_config(arch).smoke()
gen, stats = serve(cfg, batch=2, prompt_len=12, gen_len=12)
print(f"{arch}: generated {gen.shape} tokens")
print({k: round(v, 3) for k, v in stats.items()})
