"""Invariant-neuron statistics + threshold calibration (paper §4/§5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [dev] extra")
from hypothesis import given, settings, strategies as st

from repro.core import invariant as inv

SPECS = [{"name": "g", "size": 8,
          "out": [("w", 1, 1), ("b", 0, 1)], "in": []}]


def _trees(delta_scale):
    rng = np.random.RandomState(0)
    w = rng.randn(6, 8).astype(np.float32)
    b = rng.randn(8).astype(np.float32)
    prev = {"w": jnp.asarray(w), "b": jnp.asarray(b)}
    d = np.zeros((6, 8), np.float32)
    d[:, :4] = delta_scale          # neurons 0-3 change, 4-7 invariant
    new = {"w": jnp.asarray(w + d), "b": jnp.asarray(b)}
    return prev, new


def test_stats_separate_changed_neurons():
    prev, new = _trees(0.5)
    s = inv.neuron_stats(prev, new, SPECS)["g"]
    assert np.all(np.asarray(s[:4]) > np.asarray(s[4:]).max())
    np.testing.assert_allclose(np.asarray(s[4:]), 0.0, atol=1e-7)


def test_norm_stat_value():
    prev, new = _trees(1.0)
    s = np.asarray(inv.neuron_stats(prev, new, SPECS)["g"])
    w = np.asarray(prev["w"])
    b = np.asarray(prev["b"])
    den = np.sqrt((w[:, 0] ** 2).sum() + b[0] ** 2)
    np.testing.assert_allclose(s[0], np.sqrt(6.0) / (den + 1e-8), rtol=1e-5)


def test_majority_vote():
    prev, new = _trees(0.5)
    quiet = inv.neuron_stats(prev, prev, SPECS)     # all zero
    loud = inv.neuron_stats(prev, new, SPECS)
    # 3 clients: 2 quiet, 1 loud -> all neurons invariant by majority
    m = inv.invariant_mask([quiet, quiet, loud], th=1e-6)
    assert m["g"].sum() == 8
    # 1 quiet, 2 loud -> only 4 neurons invariant for the majority
    m = inv.invariant_mask([quiet, loud, loud], th=1e-6)
    assert m["g"].sum() == 4


def test_threshold_calibration_monotone():
    prev, new = _trees(0.5)
    stats = [inv.neuron_stats(prev, new, SPECS)] * 3
    th0 = inv.initial_threshold(stats)
    th = inv.calibrate_threshold(stats, n_drop_target=6, th0=th0)
    assert th >= th0
    assert inv.count_invariant(stats, th) >= 6
    # higher target -> higher (or equal) threshold
    th2 = inv.calibrate_threshold(stats, n_drop_target=8, th0=th0)
    assert th2 >= th


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 10.0), seed=st.integers(0, 1000))
def test_count_monotone_in_threshold(scale, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(5, 8).astype(np.float32)
    prev = {"w": jnp.asarray(w), "b": jnp.zeros(8)}
    new = {"w": jnp.asarray(w + scale * rng.randn(5, 8).astype(np.float32)),
           "b": jnp.zeros(8)}
    stats = [inv.neuron_stats(prev, new, SPECS)]
    ths = [1e-4, 1e-2, 1.0, 100.0]
    counts = [inv.count_invariant(stats, t) for t in ths]
    assert counts == sorted(counts)
