"""End-to-end behaviour of the reproduction (replaces the scaffold stub).

One compact FL run per dropout method on synthetic FEMNIST: checks the
paper's qualitative claims hold end to end — learning happens, FLuID cuts
round time, calibration overhead stays small (paper: <5%)."""
import numpy as np
import pytest

from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation)

pytestmark = pytest.mark.slow    # multi-minute: tier-1 only, not the CI fast tier


@pytest.fixture(scope="module")
def run():
    out = {}
    for method in ("none", "invariant"):
        sim = build_simulation(SimulationConfig(
            workload="femnist", policy=method, seed=0,
            cohort=CohortConfig(n_clients=5, straggler_ids=(0,),
                                n_data=1000)))
        hist = sim.server.run(14, eval_every=7)
        out[method] = (sim, hist)
    return out


def test_model_learns(run):
    _, hist = run["invariant"]
    accs = [h.accuracy for h in hist if h.accuracy == h.accuracy]
    assert accs[-1] > 0.06      # 62 classes, random = 0.016


def test_fluid_speeds_up_rounds(run):
    t_none = np.mean([h.round_time for h in run["none"][1][2:]])
    t_fluid = np.mean([h.round_time for h in run["invariant"][1][2:]])
    assert t_fluid < t_none * 0.98


def test_calibration_overhead_small(run):
    """Paper §6.1: calibration takes <5% of training time (here vs
    simulated round time, post-jit-warmup rounds)."""
    _, hist = run["invariant"]
    calib = np.mean([h.calib_time for h in hist[2:]])
    round_t = np.mean([h.round_time for h in hist[2:]])
    assert calib < 0.25 * round_t


def test_threshold_positive_and_finite(run):
    _, hist = run["invariant"]
    th = [h.threshold for h in hist if h.threshold > 0]
    assert th and all(np.isfinite(th))
