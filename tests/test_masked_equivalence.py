"""The paper's key identity: masked sub-model compute == physically
extracted sub-model compute, for transformer FFNs (big-model path) and the
Pallas masked_ffn kernel."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.ops import masked_ffn
from repro.models.layers import apply_ffn, init_ffn


def test_ffn_mask_equals_physical_extraction():
    cfg = (get_config("stablelm-12b").smoke()
           .with_overrides(dtype="float32", param_dtype="float32"))
    p = init_ffn(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    keep = np.sort(np.random.RandomState(0).choice(
        cfg.d_ff, size=int(cfg.d_ff * 0.75), replace=False))
    mask = jnp.zeros((cfg.d_ff,)).at[jnp.asarray(keep)].set(1.0)
    y_masked = apply_ffn(p, x, cfg, neuron_mask=mask)
    p_sub = {"w_in": p["w_in"][:, keep], "w_gate": p["w_gate"][:, keep],
             "w_out": p["w_out"][keep]}
    y_sub = apply_ffn(p_sub, x, cfg)
    np.testing.assert_allclose(y_masked, y_sub, rtol=1e-5, atol=1e-5)


def test_kernel_matches_model_ffn_block_mask():
    cfg = (get_config("stablelm-12b").smoke()
           .with_overrides(dtype="float32", param_dtype="float32", d_ff=512))
    p = init_ffn(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    bm = jnp.array([1, 0, 1, 1], jnp.int32)
    nm = jnp.repeat(bm.astype(jnp.float32), 128)
    y_model = apply_ffn(p, x[None], cfg, neuron_mask=nm)[0]
    y_kernel = masked_ffn(x, p["w_in"], p["w_out"], bm, w_gate=p["w_gate"],
                          act="silu")
    np.testing.assert_allclose(y_model, y_kernel, rtol=2e-3, atol=2e-3)
