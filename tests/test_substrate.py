"""Substrate: optimizers, checkpointing, data pipeline, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.partition import partition_iid, partition_non_iid
from repro.data.synthetic import make_dataset
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer


@pytest.mark.parametrize("name", ["sgd", "sgdm", "adamw"])
def test_optimizer_reduces_quadratic(name):
    opt = make_optimizer(name)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, lr=0.05)
    np.testing.assert_allclose(params["w"], 0.0, atol=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3)},
            "stack": [{"w": jnp.ones((2,))}, {"w": jnp.zeros((2,))}]}
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, tree, meta={"step": 3})
    back = load_checkpoint(path)
    np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(back["stack"][1]["w"], tree["stack"][1]["w"])


def test_dataset_deterministic():
    d1 = make_dataset("femnist", n=100, n_test=20, seed=3)
    d2 = make_dataset("femnist", n=100, n_test=20, seed=3)
    np.testing.assert_array_equal(d1.x, d2.x)
    np.testing.assert_array_equal(d1.y, d2.y)


def test_non_iid_partition_skews_writers():
    ds = make_dataset("femnist", n=400, n_test=10, n_partitions=8, seed=0)
    parts = partition_non_iid(ds, 4, seed=0)
    assert sum(len(p) for p in parts) == len(ds.y)
    # each client sees a strict subset of writers
    for p in parts:
        assert len(np.unique(ds.writer[p])) < 8


def test_iid_partition_covers_all():
    ds = make_dataset("cifar10", n=100, n_test=10, seed=0)
    parts = partition_iid(ds, 3, seed=0)
    got = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(got, np.arange(100))


def test_param_sharding_rules():
    mesh = make_host_mesh(1, 1)
    params = {"stack": {"seg0": {"l0": {
        "attn": {"wq": jnp.zeros((4, 8, 2, 2))},
        "ffn": {"w_in": jnp.zeros((4, 8, 16)), "w_out": jnp.zeros((4, 16, 8))},
        "norm1": {"scale": jnp.zeros((8,))}}}},
        "tok": {"embed": jnp.zeros((32, 8))}}
    with shlib.mesh_context(mesh):
        specs = shlib.param_pspecs(params)
    l0 = specs["stack"]["seg0"]["l0"]
    # model axis size 1 -> sharding demoted but rule paths must all resolve
    assert specs["tok"]["embed"] is not None
    assert l0["norm1"]["scale"] is not None


def test_shard_identity_without_mesh():
    x = jnp.ones((4, 4))
    assert shlib.shard(x, "B", "M") is x
