"""Gradient equivalence for the differentiable masked kernels (DESIGN.md §10).

`jax.grad` through the custom_vjp Pallas kernels (interpreter mode) must
match `jax.grad` of the dense `mask * params` reference to fp32 tolerance —
for the FFN (gated + ungated), batched per-row masks, and the attention-head
variant, at dropout rates {0, 0.5, all-but-one-block dropped}. Also covers
the structural zero guarantee (dropped-block dW is exactly 0, not just
small), the mask-shape validation errors, and the fleet-level contract:
a `FleetEngine(use_kernels=True)` cohort reproduces the dense cohort's
deltas, sim-times, and aggregate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.masked_attn import (masked_attention, masked_head_merge,
                                       masked_head_proj)
from repro.kernels.masked_ffn import BLOCK_NEURONS, masked_ffn, masked_ffn_batch
from repro.kernels.ref import (masked_attention_ref, masked_ffn_batch_ref,
                               masked_ffn_ref, masked_head_merge_ref,
                               masked_head_proj_ref)

ATOL = 5e-3      # fp32 interpret-mode kernels vs fp32 dense autodiff

M, D, F = 12, 32, 3 * BLOCK_NEURONS      # 3 maskable blocks
H, HD = 4, 16


def _rng(seed=0):
    return np.random.RandomState(seed)


def _ffn_weights(seed=0):
    r = _rng(seed)
    x = jnp.asarray(r.randn(M, D) * 0.5, jnp.float32)
    win = jnp.asarray(r.randn(D, F) * 0.1, jnp.float32)
    wout = jnp.asarray(r.randn(F, D) * 0.1, jnp.float32)
    wgate = jnp.asarray(r.randn(D, F) * 0.1, jnp.float32)
    return x, win, wout, wgate


# the issue's rate sweep: 0 (all kept), 0.5, and 1-block-kept
BLOCK_MASKS = {"rate0": np.array([1, 1, 1]),
               "rate05": np.array([1, 0, 1]),
               "one_block": np.array([0, 1, 0])}


def _grad_pair(f_kernel, f_ref, args, argnums):
    gk = jax.grad(lambda *a: (f_kernel(*a) ** 2).sum(), argnums=argnums)(*args)
    gr = jax.grad(lambda *a: (f_ref(*a) ** 2).sum(), argnums=argnums)(*args)
    return gk, gr


def _assert_close(gk, gr):
    for a, b in zip(gk, gr):
        err = float(np.abs(np.asarray(a) - np.asarray(b)).max())
        assert err < ATOL, err


@pytest.mark.parametrize("maskname", list(BLOCK_MASKS))
@pytest.mark.parametrize("gated", [False, True])
@pytest.mark.parametrize("act", ["gelu", "silu"])
def test_ffn_grad_matches_dense(maskname, gated, act):
    x, win, wout, wgate = _ffn_weights()
    bmask = jnp.asarray(BLOCK_MASKS[maskname], jnp.int32)
    wg = wgate if gated else None
    argnums = (0, 1, 2) + ((3,) if gated else ())
    gk, gr = _grad_pair(
        lambda *a: masked_ffn(a[0], a[1], a[2], bmask,
                              a[3] if gated else None, act=act),
        lambda *a: masked_ffn_ref(a[0], a[1], a[2], bmask,
                                  a[3] if gated else None, act=act),
        (x, win, wout, wg) if gated else (x, win, wout), argnums)
    _assert_close(gk, gr)
    # forward parity too
    yk = masked_ffn(x, win, wout, bmask, wg, act=act)
    yr = masked_ffn_ref(x, win, wout, bmask, wg, act=act)
    assert float(np.abs(np.asarray(yk) - np.asarray(yr)).max()) < ATOL


def test_ffn_dropped_block_dw_exactly_zero():
    """The §10 structural guarantee: dW of a dropped block is 0.0 — the
    accumulator was never touched — not merely small."""
    x, win, wout, wgate = _ffn_weights()
    bmask = jnp.asarray([0, 1, 0], jnp.int32)
    g = jax.grad(lambda wi, wo, wg: (
        masked_ffn(x, wi, wo, bmask, wg, act="silu") ** 2).sum(),
        argnums=(0, 1, 2))(win, wout, wgate)
    dwin = np.asarray(g[0]).reshape(D, 3, BLOCK_NEURONS)
    dwout = np.asarray(g[1]).reshape(3, BLOCK_NEURONS, D)
    dwgate = np.asarray(g[2]).reshape(D, 3, BLOCK_NEURONS)
    for j in (0, 2):
        assert np.all(dwin[:, j] == 0.0)
        assert np.all(dwout[j] == 0.0)
        assert np.all(dwgate[:, j] == 0.0)
    assert np.any(dwin[:, 1] != 0.0)


@pytest.mark.parametrize("gated", [False, True])
def test_ffn_batch_per_row_grad_matches_dense(gated):
    x, win, wout, wgate = _ffn_weights(1)
    r = _rng(2)
    rmask = (r.rand(M, F) > 0.4).astype(np.float32)
    rmask[3] = 0.0                          # one fully-dropped row
    rmask[:, BLOCK_NEURONS:2 * BLOCK_NEURONS] = 0.0   # one dead tile column
    rm = jnp.asarray(rmask)
    wg = wgate if gated else None
    argnums = (0, 1, 2) + ((3,) if gated else ())
    gk, gr = _grad_pair(
        lambda *a: masked_ffn_batch(a[0], a[1], a[2], rm,
                                    a[3] if gated else None, act="gelu"),
        lambda *a: masked_ffn_batch_ref(a[0], a[1], a[2], rm,
                                        a[3] if gated else None, act="gelu"),
        (x, win, wout, wg) if gated else (x, win, wout), argnums)
    _assert_close(gk, gr)
    # neurons masked in EVERY row never contribute to dW
    dwin = np.asarray(gk[1])
    assert np.all(dwin[:, BLOCK_NEURONS:2 * BLOCK_NEURONS] == 0.0)


HEAD_MASKS = {"rate0": np.ones(H), "rate05": np.array([1, 0, 1, 0]),
              "one_head": np.array([0, 0, 1, 0])}


@pytest.mark.parametrize("maskname", list(HEAD_MASKS))
def test_head_proj_and_merge_grad_matches_dense(maskname):
    r = _rng(3)
    hmask = jnp.asarray(HEAD_MASKS[maskname], jnp.int32)
    x = jnp.asarray(r.randn(M, D) * 0.5, jnp.float32)
    w = jnp.asarray(r.randn(D, H * HD) * 0.2, jnp.float32)
    wo = jnp.asarray(r.randn(H * HD, D) * 0.2, jnp.float32)
    a_in = jnp.asarray(r.randn(M, H * HD) * 0.3, jnp.float32)
    gk, gr = _grad_pair(
        lambda xx, ww: masked_head_proj(xx, ww, hmask),
        lambda xx, ww: masked_head_proj_ref(xx, ww, hmask),
        (x, w), (0, 1))
    _assert_close(gk, gr)
    # dropped-head dW slab exactly zero
    dw = np.asarray(gk[1]).reshape(D, H, HD)
    for j, kept in enumerate(HEAD_MASKS[maskname]):
        if kept == 0:
            assert np.all(dw[:, j] == 0.0)
    gk, gr = _grad_pair(
        lambda aa, ww: masked_head_merge(aa, ww, hmask),
        lambda aa, ww: masked_head_merge_ref(aa, ww, hmask),
        (a_in, wo), (0, 1))
    _assert_close(gk, gr)
    dw = np.asarray(gk[1]).reshape(H, HD, D)
    for j, kept in enumerate(HEAD_MASKS[maskname]):
        if kept == 0:
            assert np.all(dw[j] == 0.0)


@pytest.mark.parametrize("maskname", list(HEAD_MASKS))
def test_masked_attention_grad_matches_dense(maskname):
    r = _rng(4)
    hmask = jnp.asarray(HEAD_MASKS[maskname], jnp.int32)
    B, S = 2, 6
    x = jnp.asarray(r.randn(B, S, D) * 0.5, jnp.float32)
    wq, wk, wv = (jnp.asarray(r.randn(D, H * HD) * 0.2, jnp.float32)
                  for _ in range(3))
    wo = jnp.asarray(r.randn(H * HD, D) * 0.2, jnp.float32)
    gk, gr = _grad_pair(
        lambda *a: masked_attention(*a, hmask, n_heads=H),
        lambda *a: masked_attention_ref(*a, hmask, H),
        (x, wq, wk, wv, wo), (0, 1, 2, 3, 4))
    _assert_close(gk, gr)


def test_shape_validation_errors():
    """The silent-dense footgun fix: unaligned / mis-shaped masks raise
    clear ValueErrors instead of mis-tiling."""
    r = _rng(5)
    x = jnp.asarray(r.randn(4, D), jnp.float32)
    win = jnp.asarray(r.randn(D, F), jnp.float32)
    wout = jnp.asarray(r.randn(F, D), jnp.float32)
    with pytest.raises(ValueError, match="multiple of"):
        masked_ffn(x, win[:, :100], wout[:100], jnp.ones((1,), jnp.int32))
    with pytest.raises(ValueError, match="block_mask must be"):
        masked_ffn(x, win, wout, jnp.ones((5,), jnp.int32))
    with pytest.raises(ValueError, match="row_mask must be"):
        masked_ffn_batch(x, win, wout, jnp.ones((4, F + 1), jnp.float32))
    with pytest.raises(ValueError, match="w_out must be"):
        masked_ffn(x, win, wout[:, :D - 1], jnp.ones((3,), jnp.int32))
    w = jnp.asarray(r.randn(D, H * HD), jnp.float32)
    with pytest.raises(ValueError, match="divide evenly"):
        masked_head_proj(x, w, jnp.ones((3,), jnp.int32))
    with pytest.raises(ValueError, match="head_mask must be"):
        masked_attention(x[None], w, w, w, w.T, jnp.ones((3,), jnp.int32),
                         n_heads=H)


# ---------------------------------------------------------------------------
# fleet-level contract


def _fleet_pair(model_cls, keep_maps, seed=0):
    from repro.fl.client import FleetClient
    from repro.fl.fleet import FleetEngine

    r = _rng(seed)
    C, n = 4, 40
    x = r.randn(C * n, 28, 28, 1).astype(np.float32)
    y = r.randint(0, 62, C * n).astype(np.int32)

    def mk():
        return [FleetClient(i, model_cls, x[i * n:(i + 1) * n],
                            y[i * n:(i + 1) * n], speed=10.0, batch_size=10,
                            lr=0.05, local_epochs=1, seed=0)
                for i in range(C)]
    params = model_cls.init(jax.random.PRNGKey(0))
    dense = FleetEngine(model_cls, mk(), model_cls.UNIT_SPECS)
    kern = FleetEngine(model_cls, mk(), model_cls.UNIT_SPECS,
                       use_kernels=True)
    rates = {cid: 0.5 for cid in keep_maps}
    rd = dense.run_cohort(params, keep_maps, rates=rates)
    rk = kern.run_cohort(params, keep_maps, rates=rates)
    return params, rd, rk


@pytest.mark.parametrize("model_name", ["kernel_mlp", "kernel_attn"])
def test_fleet_use_kernels_matches_dense(model_name):
    """Acceptance gate: `use_kernels=True` cohort == dense cohort —
    deltas, sim-times, and aggregation (interpret mode)."""
    from repro.models.kernel_models import KERNEL_MODELS
    model_cls = KERNEL_MODELS[model_name]
    if model_name == "kernel_mlp":
        keep_maps = {0: {"ffn": np.arange(512)}, 1: {"ffn": np.arange(512)}}
    else:
        keep_maps = {0: {"heads": np.arange(2), "ffn": np.arange(128)},
                     1: {"heads": np.arange(2), "ffn": np.arange(128)}}
    params, rd, rk = _fleet_pair(model_cls, keep_maps)
    for a, b in zip(jax.tree.leaves(rd.deltas), jax.tree.leaves(rk.deltas)):
        assert float(np.abs(np.asarray(a) - np.asarray(b)).max()) < 1e-4
    assert rd.sim_times == rk.sim_times
    for a, b in zip(jax.tree.leaves(rd.aggregate(params)),
                    jax.tree.leaves(rk.aggregate(params))):
        assert float(np.abs(np.asarray(a) - np.asarray(b)).max()) < 1e-4


def test_fleet_use_kernels_requires_kernel_model():
    from repro.fl.client import FleetClient
    from repro.fl.fleet import FleetEngine
    from repro.models.small import FemnistCNN

    r = _rng(0)
    c = [FleetClient(0, FemnistCNN, r.randn(20, 28, 28, 1).astype(np.float32),
                     r.randint(0, 62, 20).astype(np.int32), speed=10.0)]
    with pytest.raises(ValueError, match="apply_kernels"):
        FleetEngine(FemnistCNN, c, FemnistCNN.UNIT_SPECS, use_kernels=True)


def test_unit_major_expand_and_stats():
    """The tile<0 (unit-major) grammar: expand_indices gives contiguous
    per-unit slabs and invariant stats reduce over them."""
    from repro.core.invariant import neuron_stats_for_group
    from repro.core.submodel import expand_indices

    idx = expand_indices(np.array([0, 2]), -16, 4)
    expect = np.concatenate([np.arange(0, 16), np.arange(32, 48)])
    assert np.array_equal(idx, expect)
    # stats: wq (D, H*HD) unit-major; bump head 1's slab only
    r = _rng(6)
    w0 = {"attn": {"wq": jnp.asarray(r.randn(D, H * HD), jnp.float32)}}
    bump = np.zeros((D, H * HD), np.float32)
    bump[:, HD:2 * HD] = 1.0
    w1 = {"attn": {"wq": w0["attn"]["wq"] + jnp.asarray(bump)}}
    g = {"name": "heads", "size": H,
         "out": [("attn/wq", 1, -HD)], "in": []}
    stats = np.asarray(neuron_stats_for_group(w0, w1, g))
    assert stats.shape == (H,)
    assert stats[1] > 0.0
    assert np.allclose(stats[[0, 2, 3]], 0.0)


def test_train_step_use_kernels_matches_dense():
    """launch/steps.py make_train_step(use_kernels=True): identical fp32
    loss and matching masked-FFN gradients vs the dense train step."""
    from repro.configs import get_config
    from repro.core import transformer_hooks as hooks
    from repro.launch.steps import make_train_step
    from repro.models import model as M
    from repro.optim import make_optimizer

    cfg = (get_config("stablelm-12b").smoke()
           .with_overrides(grad_accum=1, dtype="float32",
                           param_dtype="float32"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jnp.full((B, S), 3, jnp.int32),
             "targets": jnp.ones((B, S), jnp.int32)}
    masks = hooks.full_masks(cfg)

    def drop_half(m):
        m = np.asarray(m).copy()
        m[..., m.shape[-1] // 2:] = 0.0
        return jnp.asarray(m)
    masks = jax.tree.map(drop_half, masks)
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    sd = jax.jit(make_train_step(cfg, with_masks=True))
    sk = jax.jit(make_train_step(cfg, with_masks=True, use_kernels=True))
    pd, _, md = sd(params, opt_state, batch, masks)
    pk, _, mk = sk(params, opt_state, batch, masks)
    assert abs(float(md["loss"]) - float(mk["loss"])) < 1e-5
    # post-Adam params agree to optimizer-rescaled fp tolerance
    for a, b in zip(jax.tree.leaves(pd), jax.tree.leaves(pk)):
        assert float(np.abs(np.asarray(a) - np.asarray(b)).max()) < 1e-3
