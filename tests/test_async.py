"""Async buffered rounds (fl/async_rounds.py, core/aggregate.py staleness).

The acceptance contracts:
  * ZERO-SPREAD EQUIVALENCE: with a pass-through ArrivalModel and
    buffer_k = concurrency = cohort_size, the async run is BITWISE equal
    to the synchronous fleet run — params, store state, calibration
    decisions — because every identity in the chain is exact (lognormal(0)
    multiplier == 1.0, staleness 0 => scale == 1.0, w * 1.0 == w, and the
    rebuilt buffer bank reproduces the dispatch bank row-for-row);
  * a uniformly max-stale buffer aggregates EXACTLY like plain masked
    FedAvg (the (1+s)^(-a) weights max-normalize to x/x == 1.0);
  * stragglers that miss a buffer are delivered later with staleness > 0,
    never dropped — including clients that drop mid-round and reconnect;
  * buffer_k=1 (the fully streaming limit) works;
  * in-flight bookkeeping: a dispatched-but-unarrived client is never
    sampled into a new dispatch group.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import (aggregate_buffered, aggregate_stacked,
                                  staleness_scale)
from repro.core.straggler import ArrivalModel
from repro.fl.async_rounds import (AsyncBufferedBackend, AsyncConfig,
                                   AsyncPopulationSim)
from repro.fl.population import ClientStore, PopulationConfig, build_population

jax.config.update("jax_platform_name", "cpu")


def _pop_cfg(**over):
    kw = dict(n_clients=1500, cohort_size=8, workload="synth",
              backend="async", n_partitions=16, samples_per_partition=40,
              straggler_frac_pop=0.2, seed=42)
    kw.update(over)
    return PopulationConfig(**kw)


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# config + arrival-model validation


def test_async_config_validation():
    with pytest.raises(ValueError, match="buffer_k"):
        AsyncConfig(buffer_k=0)
    with pytest.raises(ValueError, match="concurrency"):
        AsyncConfig(buffer_k=8, concurrency=4)
    with pytest.raises(ValueError, match="staleness_exponent"):
        AsyncConfig(staleness_exponent=-0.1)
    with pytest.raises(ValueError, match="drop_prob"):
        ArrivalModel(drop_prob=1.0)
    with pytest.raises(ValueError, match="tail_sigma"):
        ArrivalModel(tail_sigma=-1.0)


def test_arrival_model_zero_config_is_exact_passthrough():
    m = ArrivalModel()
    for t in (0.5, 3.25, 100.0):
        lat, drops = m.draw(t)
        assert lat == t and drops == 0   # bitwise; no RNG consumed
    m2 = ArrivalModel(drop_prob=0.8, reconnect_mean=10.0, max_drops=3,
                      seed=7)
    draws = [m2.draw(1.0) for _ in range(50)]
    assert any(d for _, d in draws)                  # dropouts happen
    assert all(lat >= 1.0 for lat, _ in draws)       # reconnect only delays
    assert all(d <= 3 for _, d in draws)             # capped
    assert any(lat > 1.0 for lat, d in draws if d)   # pause adds latency


# ---------------------------------------------------------------------------
# staleness weighting


def _stacked_case(seed=0):
    rng = np.random.RandomState(seed)
    params = {"w": jnp.asarray(rng.randn(6, 4), jnp.float32),
              "b": jnp.asarray(rng.randn(4), jnp.float32)}
    mask = {"w": jnp.asarray(rng.rand(6, 4) > 0.5, jnp.float32),
            "b": jnp.asarray(rng.rand(4) > 0.5, jnp.float32)}
    bank = jax.tree.map(lambda p, m: jnp.stack([jnp.ones_like(p), m]),
                        params, mask)
    deltas = {k: jnp.asarray(rng.randn(3, *params[k].shape), jnp.float32)
              for k in params}
    # client 1 is the straggler: its delta arrives mask-pre-zeroed
    deltas = jax.tree.map(
        lambda d, m: d.at[1].set(d[1] * m), deltas, mask)
    idx = jnp.asarray([0, 1, 0], jnp.int32)
    weights = jnp.asarray([20.0, 10.0, 30.0], jnp.float32)
    return params, deltas, weights, bank, idx


def test_staleness_scale_exact_identities():
    s = staleness_scale(np.zeros(4, np.float32), 0.5)
    assert np.array_equal(np.asarray(s), np.ones(4, np.float32))
    s = staleness_scale(np.full(5, 7.0, np.float32), 0.5)   # uniform stale
    assert np.array_equal(np.asarray(s), np.ones(5, np.float32))
    s = np.asarray(staleness_scale(np.asarray([0., 1., 3.], np.float32),
                                   0.5))
    assert s[0] == 1.0 and s[0] > s[1] > s[2] > 0.0
    # exponent 0: staleness ignored entirely
    s = staleness_scale(np.asarray([0., 5., 2.], np.float32), 0.0)
    assert np.array_equal(np.asarray(s), np.ones(3, np.float32))


def test_max_stale_buffer_is_plain_masked_fedavg():
    """Every arrival equally late => weights normalize to 1.0 exactly and
    the buffer aggregates bitwise like a synchronous masked FedAvg."""
    params, deltas, weights, bank, idx = _stacked_case()
    base = aggregate_stacked(params, deltas, weights, bank, idx)
    for s in (0.0, 4.0):
        stale = np.full(3, s, np.float32)
        got = aggregate_buffered(params, deltas, weights, bank, idx,
                                 stale, 0.5)
        assert _leaves_equal(base, got)
    # mixed staleness must actually discount (sanity that the knob works)
    mixed = aggregate_buffered(params, deltas, weights, bank, idx,
                               np.asarray([0., 4., 0.], np.float32), 0.5)
    assert not _leaves_equal(base, mixed)


# ---------------------------------------------------------------------------
# backend mechanics


def test_backend_buffer_k1_streams_one_arrival_per_round():
    acfg = AsyncConfig(buffer_k=1, concurrency=3,
                       arrival=ArrivalModel(tail_sigma=0.5, seed=1))
    sim = build_population(_pop_cfg(n_clients=400, n_partitions=8,
                                    async_cfg=acfg))
    assert isinstance(sim, AsyncPopulationSim)
    hist = sim.run(5)
    assert len(hist) == 5
    be = sim.backend
    assert all(len(h.stragglers) >= 0 for h in hist)
    assert [h.clock for h in hist] == sorted(h.clock for h in hist)
    # exactly one arrival per buffer, bookkeeping closed
    assert be.n_dispatched == 5 * 1 + (3 - 1) + len([])  # 3 initial + 1/round
    assert len(be.in_flight_ids) == 2                    # concurrency - K
    assert int(np.asarray(sim.store.in_flight).sum()) == 2
    assert int(np.asarray(sim.store.rounds_participated).sum()) == 5


def test_straggler_misses_buffer_lands_later_with_staleness():
    acfg = AsyncConfig(buffer_k=2, concurrency=6, staleness_exponent=0.5,
                       arrival=ArrivalModel(tail_sigma=1.0, seed=5))
    sim = build_population(_pop_cfg(async_cfg=acfg))
    sim.run(8)
    stales = [h.staleness_max for h in sim.server.history]
    assert max(stales) >= 1.0        # someone missed at least one buffer
    # ... and was aggregated anyway: every drained arrival became a store
    # observation (nothing dropped)
    assert int(np.asarray(sim.store.rounds_participated).sum()) == 8 * 2


def test_midround_dropout_reconnects_and_is_aggregated():
    acfg = AsyncConfig(buffer_k=2, concurrency=4,
                       arrival=ArrivalModel(drop_prob=0.6,
                                            reconnect_mean=25.0, seed=9))
    sim = build_population(_pop_cfg(async_cfg=acfg))
    sim.run(6)
    be = sim.backend
    assert be.total_drops > 0                      # dropouts happened
    dropped = [a for a in be.last_result.arrivals if a.drops > 0]
    hist_stale = [h.staleness_max for h in sim.server.history]
    # a reconnect pause pushes a client past buffers dispatched after it
    assert max(hist_stale) >= 1.0
    # conservation: every dispatch is either drained or still in flight
    assert be.n_dispatched == 6 * 2 + len(be.in_flight_ids)
    # reconnect delays, never destroys: arrivals with drops carry the
    # exponential pause in their latency
    for a in dropped:
        assert a.latency > 0.0
    assert int(np.asarray(sim.store.rounds_participated).sum()) == 6 * 2


def test_flash_crowd_dispatches_extra_then_drains():
    acfg = AsyncConfig(buffer_k=2, concurrency=4,
                       flash_crowds=((1, 3),),
                       arrival=ArrivalModel(tail_sigma=0.3, seed=2))
    sim = build_population(_pop_cfg(async_cfg=acfg))
    sim.run_round()                              # r0: 4 dispatched, 2 drain
    assert sim.backend.n_dispatched == 4
    sim.run_round()                              # r1: top-up 2 + flash 3
    assert sim.backend.n_dispatched == 4 + 5
    assert len(sim.backend.in_flight_ids) == 4 + 5 - 2 * 2
    sim.run_round()                              # r2: surplus absorbs top-up
    assert len(sim.backend.in_flight_ids) <= 5
    # store mirror agrees with the backend at every step
    assert (int(np.asarray(sim.store.in_flight).sum())
            == len(sim.backend.in_flight_ids))


def test_make_backend_async_is_stateful_across_rounds():
    from repro.fl.rounds import make_backend
    from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                     build_simulation)
    ssim = build_simulation(SimulationConfig(
        workload="femnist", backend="fleet",
        cohort=CohortConfig(n_clients=4, n_data=400), seed=0))
    acfg = AsyncConfig(buffer_k=2, concurrency=2,
                       arrival=ArrivalModel(tail_sigma=0.4, seed=0))
    be = make_backend("async", ssim.model_cls, ssim.clients,
                      ssim.model_cls.UNIT_SPECS, async_cfg=acfg)
    assert isinstance(be, AsyncBufferedBackend)
    params = ssim.server.params
    r1 = be.run_round(params, {}, {})
    assert len(r1.sim_times) == 2 and be.version == 1
    assert np.all(r1.staleness == 0.0)
    # in-flight clients are skipped on redispatch; clock only advances
    r2 = be.run_round(params, {}, {})
    assert r2.clock >= r1.clock
    assert set(r1.sim_times) | set(r2.sim_times) <= {c.id for c in
                                                     ssim.clients}
    new = r2.aggregate(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(new))
    assert len(r2.updates()) == 2


def test_async_backend_refuses_unfillable_buffer():
    from repro.fl.rounds import make_backend
    from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                     build_simulation)
    ssim = build_simulation(SimulationConfig(
        workload="femnist", backend="fleet",
        cohort=CohortConfig(n_clients=2, n_data=300), seed=0))
    be = make_backend("async", ssim.model_cls, ssim.clients,
                      ssim.model_cls.UNIT_SPECS,
                      async_cfg=AsyncConfig(buffer_k=4, concurrency=4))
    with pytest.raises(RuntimeError, match="cannot fill"):
        be.run_round(ssim.server.params, {}, {})


# ---------------------------------------------------------------------------
# in-flight bookkeeping at the store


def test_sample_cohort_available_only_excludes_in_flight():
    st = ClientStore.empty(20).register(np.arange(20), np.full(20, 10.0),
                                        np.zeros(20))
    st = st.mark_in_flight(np.arange(0, 20, 2), True)
    key = jax.random.PRNGKey(3)
    ids = np.asarray(st.sample_cohort(key, 10, available_only=True))
    assert np.all(ids % 2 == 1)                  # only the idle half
    # plain sampling still sees everyone
    assert len(np.asarray(st.sample_cohort(key, 20))) == 20
    # and the guard counts availability, not activity
    with pytest.raises(ValueError, match="available"):
        st.sample_cohort(key, 11, available_only=True)
    st2 = st.mark_in_flight(np.arange(0, 20, 2), False)
    assert len(np.asarray(st2.sample_cohort(key, 20,
                                            available_only=True))) == 20


# ---------------------------------------------------------------------------
# the equivalence anchor


def test_zero_spread_async_equals_fleet_bitwise():
    """buffer_k = concurrency = cohort_size + pass-through arrivals: the
    async schedule degenerates to the synchronous barrier, and everything
    — aggregated params, store history, calibration decisions — must be
    BITWISE identical to the fleet backend, including rounds where
    invariant dropout assigns sub-models to stragglers."""
    base = dict(n_clients=1500, cohort_size=8, workload="synth",
                n_partitions=16, samples_per_partition=40,
                straggler_frac_pop=0.2, seed=42)
    sync = build_population(PopulationConfig(backend="fleet", **base))
    sync.run(4)
    asy = build_population(PopulationConfig(
        backend="async",
        async_cfg=AsyncConfig(buffer_k=8, concurrency=8), **base))
    asy.run(4)

    assert _leaves_equal(sync.server.params, asy.server.params)
    for f in ("speed_ema", "speed_hist", "straggler_ema", "dropout_rate",
              "rounds_participated", "in_flight"):
        assert _leaves_equal(getattr(sync.store, f),
                             getattr(asy.store, f)), f
    hs, ha = sync.server.history, asy.server.history
    assert [h.round_time for h in hs] == [h.round_time for h in ha]
    assert [h.stragglers for h in hs] == [h.stragglers for h in ha]
    assert [h.rates for h in hs] == [h.rates for h in ha]
    assert [h.threshold for h in hs] == [h.threshold for h in ha]
    assert all(h.staleness_max == 0.0 for h in ha)
    # at least one round actually exercised the masked (straggler) path,
    # otherwise this test proves less than it claims
    assert any(h.stragglers for h in hs)
    # async clock == sum of synchronous barrier times in the degenerate case
    assert ha[-1].clock == pytest.approx(sum(h.round_time for h in hs))
