"""Vectorized fleet engine == sequential reference path (fl/fleet.py).

The acceptance contract: with the same seeds, one vmapped cohort round
reproduces the per-client sequential round — deltas (full-model AND
masked-straggler clients), emulated times, and the aggregated params — up
to float summation order; and the fused device-side aggregation matches
core.aggregate.aggregate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import submodel as sub
from repro.core.aggregate import ClientUpdate, aggregate, aggregate_stacked
from repro.core.dropout import DropoutPolicy
from repro.fl.client import FleetClient, SimClient
from repro.fl.fleet import FleetEngine
from repro.fl.simulation import build_simulation


def _tree_close(a, b, atol, rtol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), atol=atol, rtol=rtol), a, b)


@pytest.fixture(scope="module")
def fleet_sim():
    return build_simulation("femnist", n_clients=4, straggler_ids=(0,),
                            method="invariant", n_data=240, seed=0,
                            backend="fleet")


def _clone_seq_client(c, model_cls):
    return SimClient(c.id, model_cls, c.x, c.y, speed=c.speed,
                     batch_size=c.batch_size, local_epochs=c.local_epochs,
                     lr=c.lr, seed=c.seed)


def test_full_cohort_deltas_match_sequential(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    seq = [_clone_seq_client(c, fleet_sim.model_cls)
           for c in engine.clients]
    # fresh fleet clients so both paths draw the same RNG stream
    flt = [FleetClient(c.id, fleet_sim.model_cls, c.x, c.y, speed=c.speed,
                       batch_size=c.batch_size, local_epochs=c.local_epochs,
                       lr=c.lr, seed=c.seed) for c in engine.clients]
    eng = FleetEngine(fleet_sim.model_cls, flt, engine.unit_specs)
    cohort = eng.run_cohort(params, {})
    updates = cohort.updates()
    for c, u in zip(seq, updates):
        ref = c.train(params)
        assert u.client_id == ref.client_id
        assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
        _tree_close(u.delta, ref.delta, atol=2e-5)


def test_masked_straggler_delta_matches_extracted_submodel(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("ordered", engine.unit_specs, seed=0)
    keep = policy.keep_map(0.5)
    c0 = engine.clients[0]
    seq = _clone_seq_client(c0, fleet_sim.model_cls)
    flt = [FleetClient(c.id, fleet_sim.model_cls, c.x, c.y, speed=c.speed,
                       batch_size=c.batch_size, local_epochs=c.local_epochs,
                       lr=c.lr, seed=c.seed) for c in engine.clients]
    eng = FleetEngine(fleet_sim.model_cls, flt, engine.unit_specs)
    cohort = eng.run_cohort(params, {0: keep}, {0: 0.5})
    u = cohort.updates()[0]
    # sequential reference: physically extracted sub-model + re-embedding
    sub_params = sub.extract(params, engine.unit_specs, keep)
    ref = seq.train(sub_params, keep_map=keep, rate=0.5)
    full_delta, mask = sub.embed_delta(ref.delta, params,
                                       engine.unit_specs, keep)
    assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
    _tree_close(u.mask, mask, atol=0)
    _tree_close(u.delta, full_delta, atol=2e-5)
    # fleet deltas come back already mask-zeroed
    jax.tree.map(lambda d, m: np.testing.assert_array_equal(
        np.asarray(d) * (1 - np.asarray(m)), 0.0), u.delta, u.mask)


def test_device_aggregation_matches_reference(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("ordered", engine.unit_specs, seed=0)
    keep = policy.keep_map(0.65)
    cohort = engine.run_cohort(params, {1: keep}, {1: 0.65})
    got = cohort.aggregate(params)
    want = aggregate(params, cohort.updates())
    _tree_close(got, want, atol=1e-5)


def test_aggregate_stacked_pure_tree():
    """aggregate_stacked == aggregate on a hand-built masked cohort."""
    rng = np.random.RandomState(0)
    p = {"a": jnp.asarray(rng.randn(4, 3).astype(np.float32)),
         "b": jnp.asarray(rng.randn(5).astype(np.float32))}
    mask = {"a": jnp.asarray((rng.rand(4, 3) > 0.5).astype(np.float32)),
            "b": jnp.asarray((rng.rand(5) > 0.5).astype(np.float32))}
    ones = jax.tree.map(lambda x: jnp.ones_like(x), p)
    deltas = [jax.tree.map(lambda x: jnp.asarray(
        rng.randn(*x.shape).astype(np.float32)), p) for _ in range(3)]
    deltas[2] = jax.tree.map(lambda d, m: d * m, deltas[2], mask)
    weights = [2.0, 5.0, 3.0]
    updates = [ClientUpdate(deltas[0], 2, None, client_id=0),
               ClientUpdate(deltas[1], 5, None, client_id=1),
               ClientUpdate(deltas[2], 3, mask, client_id=2)]
    want = aggregate(p, updates)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *deltas)
    bank = jax.tree.map(lambda a, b: jnp.stack([a, b]), ones, mask)
    got = aggregate_stacked(p, stacked, jnp.asarray(weights),
                            bank, jnp.asarray([0, 0, 1], jnp.int32))
    _tree_close(got, want, atol=1e-6)


def test_mask_bank_dedupes_identical_keep_maps(fleet_sim):
    """Two stragglers with the same keep-map share one bank row: K = 2
    (ones + 1 distinct), not 1 + n_stragglers."""
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("ordered", engine.unit_specs, seed=0)
    keep = policy.keep_map(0.5)
    keep2 = {g: v.copy() for g, v in keep.items()}
    cohort = engine.run_cohort(params, {0: keep, 1: keep2},
                               {0: 0.5, 1: 0.5})
    assert jax.tree.leaves(cohort.mask_bank)[0].shape[0] == 2
    assert int(cohort.mask_idx[0]) == 1 and int(cohort.mask_idx[1]) == 1


def test_keep_mask_matches_embed_delta_mask(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("random", engine.unit_specs, seed=3)
    keep = policy.keep_map(0.75)
    m = sub.keep_mask(params, engine.unit_specs, keep)
    zero_sub = jax.tree.map(jnp.zeros_like,
                            sub.extract(params, engine.unit_specs, keep))
    _, m_ref = sub.embed_delta(zero_sub, params, engine.unit_specs, keep)
    _tree_close(m, m_ref, atol=0)
    n_sub, _ = sub.submodel_sizes(params, engine.unit_specs, keep)
    total = sum(float(x.sum()) for x in jax.tree.leaves(m))
    assert int(total) == n_sub


def test_end_to_end_fleet_matches_sequential_rounds(fleet_sim):
    kw = dict(workload="femnist", n_clients=4, straggler_ids=(0,),
              method="invariant", n_data=240, seed=0)
    seq = build_simulation(backend="sequential", **kw)
    flt = build_simulation(backend="fleet", **kw)
    hs = seq.server.run(3)
    hf = flt.server.run(3)
    for a, b in zip(hs, hf):
        assert a.round_time == pytest.approx(b.round_time, rel=1e-9)
        assert a.stragglers == b.stragglers
        assert a.rates == b.rates
    _tree_close(seq.server.params, flt.server.params, atol=5e-4)


def test_heterogeneous_lr_rejected():
    x = np.zeros((40, 2), np.float32)
    y = np.zeros((40,), np.int64)

    class Tiny:
        pass
    a = FleetClient(0, Tiny, x, y, speed=1.0, lr=0.01)
    b = FleetClient(1, Tiny, x, y, speed=1.0, lr=0.02)
    with pytest.raises(ValueError, match="uniform"):
        FleetEngine(Tiny, [a, b], [])


def test_ragged_shards_match_sequential(fleet_sim):
    """Clients whose shards are smaller than the batch size (and of unequal
    step counts) still reproduce the sequential path via batch padding +
    per-sample loss weights."""
    model_cls = fleet_sim.model_cls
    src = fleet_sim.server.engine.clients
    sizes = [7, 23, 40]     # all below/above the batch size of 10
    seq, flt = [], []
    for cid, n in enumerate(sizes):
        c = src[0]
        kw = dict(speed=1.0, batch_size=10, lr=c.lr, seed=5)
        seq.append(SimClient(cid, model_cls, c.x[:n], c.y[:n], **kw))
        flt.append(FleetClient(cid, model_cls, c.x[:n], c.y[:n], **kw))
    params = fleet_sim.server.params
    eng = FleetEngine(model_cls, flt, fleet_sim.server.engine.unit_specs)
    cohort = eng.run_cohort(params, {})
    for c, u in zip(seq, cohort.updates()):
        ref = c.train(params)
        assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
        _tree_close(u.delta, ref.delta, atol=2e-5)
