"""Vectorized fleet engine == sequential reference path (fl/fleet.py).

The acceptance contract: with the same seeds, one vmapped cohort round
reproduces the per-client sequential round — deltas (full-model AND
masked-straggler clients), emulated times, and the aggregated params — up
to float summation order; and the fused device-side aggregation matches
core.aggregate.aggregate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import submodel as sub
from repro.core.aggregate import ClientUpdate, aggregate, aggregate_stacked
from repro.core.dropout import DropoutPolicy
from repro.fl.client import FleetClient, SimClient
from repro.fl.fleet import FleetEngine
from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation)


def _tree_close(a, b, atol, rtol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), atol=atol, rtol=rtol), a, b)


def _cfg(backend):
    return SimulationConfig(
        workload="femnist", backend=backend, policy="invariant", seed=0,
        cohort=CohortConfig(n_clients=4, straggler_ids=(0,), n_data=240))


@pytest.fixture(scope="module")
def fleet_sim():
    return build_simulation(_cfg("fleet"))


def _clone_seq_client(c, model_cls):
    return SimClient(c.id, model_cls, c.x, c.y, speed=c.speed,
                     batch_size=c.batch_size, local_epochs=c.local_epochs,
                     lr=c.lr, seed=c.seed)


def test_full_cohort_deltas_match_sequential(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    seq = [_clone_seq_client(c, fleet_sim.model_cls)
           for c in engine.clients]
    # fresh fleet clients so both paths draw the same RNG stream
    flt = [FleetClient(c.id, fleet_sim.model_cls, c.x, c.y, speed=c.speed,
                       batch_size=c.batch_size, local_epochs=c.local_epochs,
                       lr=c.lr, seed=c.seed) for c in engine.clients]
    eng = FleetEngine(fleet_sim.model_cls, flt, engine.unit_specs)
    cohort = eng.run_cohort(params, {})
    updates = cohort.updates()
    for c, u in zip(seq, updates):
        ref = c.train(params)
        assert u.client_id == ref.client_id
        assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
        _tree_close(u.delta, ref.delta, atol=2e-5)


def test_masked_straggler_delta_matches_extracted_submodel(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("ordered", engine.unit_specs, seed=0)
    keep = policy.keep_map(0.5)
    c0 = engine.clients[0]
    seq = _clone_seq_client(c0, fleet_sim.model_cls)
    flt = [FleetClient(c.id, fleet_sim.model_cls, c.x, c.y, speed=c.speed,
                       batch_size=c.batch_size, local_epochs=c.local_epochs,
                       lr=c.lr, seed=c.seed) for c in engine.clients]
    eng = FleetEngine(fleet_sim.model_cls, flt, engine.unit_specs)
    cohort = eng.run_cohort(params, {0: keep}, {0: 0.5})
    u = cohort.updates()[0]
    # sequential reference: physically extracted sub-model + re-embedding
    sub_params = sub.extract(params, engine.unit_specs, keep)
    ref = seq.train(sub_params, keep_map=keep, rate=0.5)
    full_delta, mask = sub.embed_delta(ref.delta, params,
                                       engine.unit_specs, keep)
    assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
    _tree_close(u.mask, mask, atol=0)
    _tree_close(u.delta, full_delta, atol=2e-5)
    # fleet deltas come back already mask-zeroed
    jax.tree.map(lambda d, m: np.testing.assert_array_equal(
        np.asarray(d) * (1 - np.asarray(m)), 0.0), u.delta, u.mask)


def test_device_aggregation_matches_reference(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("ordered", engine.unit_specs, seed=0)
    keep = policy.keep_map(0.65)
    cohort = engine.run_cohort(params, {1: keep}, {1: 0.65})
    got = cohort.aggregate(params)
    want = aggregate(params, cohort.updates())
    _tree_close(got, want, atol=1e-5)


def test_aggregate_stacked_pure_tree():
    """aggregate_stacked == aggregate on a hand-built masked cohort."""
    rng = np.random.RandomState(0)
    p = {"a": jnp.asarray(rng.randn(4, 3).astype(np.float32)),
         "b": jnp.asarray(rng.randn(5).astype(np.float32))}
    mask = {"a": jnp.asarray((rng.rand(4, 3) > 0.5).astype(np.float32)),
            "b": jnp.asarray((rng.rand(5) > 0.5).astype(np.float32))}
    ones = jax.tree.map(lambda x: jnp.ones_like(x), p)
    deltas = [jax.tree.map(lambda x: jnp.asarray(
        rng.randn(*x.shape).astype(np.float32)), p) for _ in range(3)]
    deltas[2] = jax.tree.map(lambda d, m: d * m, deltas[2], mask)
    weights = [2.0, 5.0, 3.0]
    updates = [ClientUpdate(deltas[0], 2, None, client_id=0),
               ClientUpdate(deltas[1], 5, None, client_id=1),
               ClientUpdate(deltas[2], 3, mask, client_id=2)]
    want = aggregate(p, updates)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *deltas)
    bank = jax.tree.map(lambda a, b: jnp.stack([a, b]), ones, mask)
    got = aggregate_stacked(p, stacked, jnp.asarray(weights),
                            bank, jnp.asarray([0, 0, 1], jnp.int32))
    _tree_close(got, want, atol=1e-6)


def test_mask_bank_dedupes_identical_keep_maps(fleet_sim):
    """Two stragglers with the same keep-map share one bank row: K = 2
    (ones + 1 distinct), not 1 + n_stragglers."""
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("ordered", engine.unit_specs, seed=0)
    keep = policy.keep_map(0.5)
    keep2 = {g: v.copy() for g, v in keep.items()}
    cohort = engine.run_cohort(params, {0: keep, 1: keep2},
                               {0: 0.5, 1: 0.5})
    assert jax.tree.leaves(cohort.mask_bank)[0].shape[0] == 2
    assert int(cohort.mask_idx[0]) == 1 and int(cohort.mask_idx[1]) == 1


def test_keep_mask_matches_embed_delta_mask(fleet_sim):
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    policy = DropoutPolicy("random", engine.unit_specs, seed=3)
    keep = policy.keep_map(0.75)
    m = sub.keep_mask(params, engine.unit_specs, keep)
    zero_sub = jax.tree.map(jnp.zeros_like,
                            sub.extract(params, engine.unit_specs, keep))
    _, m_ref = sub.embed_delta(zero_sub, params, engine.unit_specs, keep)
    _tree_close(m, m_ref, atol=0)
    n_sub, _ = sub.submodel_sizes(params, engine.unit_specs, keep)
    total = sum(float(x.sum()) for x in jax.tree.leaves(m))
    assert int(total) == n_sub


def test_end_to_end_fleet_matches_sequential_rounds(fleet_sim):
    seq = build_simulation(_cfg("sequential"))
    flt = build_simulation(_cfg("fleet"))
    hs = seq.server.run(3)
    hf = flt.server.run(3)
    for a, b in zip(hs, hf):
        assert a.round_time == pytest.approx(b.round_time, rel=1e-9)
        assert a.stragglers == b.stragglers
        assert a.rates == b.rates
    _tree_close(seq.server.params, flt.server.params, atol=5e-4)


def test_heterogeneous_lr_and_epochs_match_sequential(fleet_sim):
    """Per-client (lr, local_epochs) are vmapped data: a mixed cohort still
    reproduces each client's own sequential run."""
    model_cls = fleet_sim.model_cls
    src = fleet_sim.server.engine.clients
    lrs = [0.004, 0.012, 0.002]
    epochs = [1, 2, 1]
    seq, flt = [], []
    for cid in range(3):
        c = src[cid]
        kw = dict(speed=1.0, batch_size=c.batch_size, lr=lrs[cid],
                  local_epochs=epochs[cid], seed=7)
        seq.append(SimClient(cid, model_cls, c.x, c.y, **kw))
        flt.append(FleetClient(cid, model_cls, c.x, c.y, **kw))
    params = fleet_sim.server.params
    eng = FleetEngine(model_cls, flt, fleet_sim.server.engine.unit_specs)
    cohort = eng.run_cohort(params, {})
    for c, u in zip(seq, cohort.updates()):
        ref = c.train(params)
        assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
        _tree_close(u.delta, ref.delta, atol=2e-5)


def test_lr_override_uniform_equivalence(fleet_sim):
    """run_cohort(lr=scalar) == a cohort whose clients all carry that lr,
    and a (C,)-array override with identical entries matches the scalar."""
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params

    def fresh():
        return FleetEngine(fleet_sim.model_cls, [
            FleetClient(c.id, fleet_sim.model_cls, c.x, c.y, speed=c.speed,
                        batch_size=c.batch_size, local_epochs=c.local_epochs,
                        lr=c.lr, seed=c.seed) for c in engine.clients],
            engine.unit_specs)
    a = fresh().run_cohort(params, {}, lr=0.009)
    b = fresh().run_cohort(params, {},
                           lr=np.full(len(engine.clients), 0.009))
    _tree_close(a.deltas, b.deltas, atol=0)


def test_n_steps_override_caps_local_steps(fleet_sim):
    """n_steps zero-weights the tail: capping client 0 to one step equals a
    client that only had one batch worth of local SGD."""
    engine = fleet_sim.server.engine
    params = fleet_sim.server.params
    clients = [FleetClient(c.id, fleet_sim.model_cls, c.x, c.y, speed=c.speed,
                           batch_size=c.batch_size,
                           local_epochs=c.local_epochs, lr=c.lr, seed=c.seed)
               for c in engine.clients]
    eng = FleetEngine(fleet_sim.model_cls, clients, engine.unit_specs)
    caps = eng.client_steps.copy()
    caps[0] = 1
    cohort = eng.run_cohort(params, {}, n_steps=caps)
    # reference: client 0 truncated to one batch (same RNG permutation)
    c0 = engine.clients[0]
    ref_c = SimClient(0, fleet_sim.model_cls, c0.x, c0.y, speed=c0.speed,
                      batch_size=c0.batch_size, local_epochs=c0.local_epochs,
                      lr=c0.lr, seed=c0.seed)
    order = ref_c._epoch_order()
    bs = ref_c.eff_batch_size
    import jax.numpy as jnp2
    from repro.fl.client import _train_fn
    run = _train_fn(fleet_sim.model_cls)
    xs = jnp2.asarray(c0.x[order[:bs]][None])
    ys = jnp2.asarray(c0.y[order[:bs]][None])
    new_p = run(params, xs, ys, c0.lr)
    want = jax.tree.map(lambda a_, b_: a_ - b_, new_p, params)
    got = jax.tree.map(lambda d: d[0], cohort.deltas)
    _tree_close(got, want, atol=2e-5)
    with pytest.raises(ValueError, match="n_steps"):
        eng.run_cohort(params, {}, n_steps=np.array([1]))


def test_ragged_shards_match_sequential(fleet_sim):
    """Clients whose shards are smaller than the batch size (and of unequal
    step counts) still reproduce the sequential path via batch padding +
    per-sample loss weights."""
    model_cls = fleet_sim.model_cls
    src = fleet_sim.server.engine.clients
    sizes = [7, 23, 40]     # all below/above the batch size of 10
    seq, flt = [], []
    for cid, n in enumerate(sizes):
        c = src[0]
        kw = dict(speed=1.0, batch_size=10, lr=c.lr, seed=5)
        seq.append(SimClient(cid, model_cls, c.x[:n], c.y[:n], **kw))
        flt.append(FleetClient(cid, model_cls, c.x[:n], c.y[:n], **kw))
    params = fleet_sim.server.params
    eng = FleetEngine(model_cls, flt, fleet_sim.server.engine.unit_specs)
    cohort = eng.run_cohort(params, {})
    for c, u in zip(seq, cohort.updates()):
        ref = c.train(params)
        assert u.sim_time == pytest.approx(ref.sim_time, rel=1e-12)
        _tree_close(u.delta, ref.delta, atol=2e-5)
