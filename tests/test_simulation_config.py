"""Typed SimulationConfig API (fl/simulation).

Contract: simulations are described by the SimulationConfig dataclass —
the legacy ``build_simulation(workload, **kwargs)`` shim completed its
deprecation cycle and now fails loudly with a migration hint. Unknown
policies/backends/workloads fail at construction; per-client
(lr, local_epochs) heterogeneity flows from CohortConfig into the fleet
engine's vmapped arrays.
"""
import numpy as np
import pytest

from repro.fl import CohortConfig, SimulationConfig, build_simulation
from repro.fl.simulation import run_experiment


def _mini(**over):
    co = over.pop("cohort", CohortConfig(n_clients=3, n_data=240))
    return SimulationConfig(workload="femnist", cohort=co, **over)


def test_config_path_builds_and_runs():
    sim = build_simulation(_mini(backend="fleet"))
    log = sim.server.run_round()
    assert log.round_time > 0
    assert sim.backend == "fleet"


def test_legacy_kwargs_shape_removed():
    """The PR-2 DeprecationWarning shim is gone: positional workload
    strings (and any non-SimulationConfig argument) raise TypeError with
    a migration pointer."""
    with pytest.raises(TypeError, match="SimulationConfig"):
        build_simulation("femnist")
    with pytest.raises(TypeError, match="removed"):
        build_simulation({"workload": "femnist", "n_clients": 3})
    # the kwargs never existed on the typed signature either
    with pytest.raises(TypeError):
        build_simulation(_mini(), n_clients=9)
    with pytest.raises(TypeError):
        build_simulation("femnist", n_clients=2, n_data=240)


def test_run_experiment_takes_config_only():
    sim, hist = run_experiment(_mini(), 1, eval_every=0)
    assert len(hist) == 1
    with pytest.raises(TypeError, match="SimulationConfig"):
        run_experiment("femnist", 1)


def test_unknown_policy_backend_workload_rejected():
    with pytest.raises(ValueError, match="policy"):
        _mini(policy="magic")
    with pytest.raises(ValueError, match="backend"):
        _mini(backend="gpu_cluster")
    with pytest.raises(ValueError, match="workload"):
        SimulationConfig(workload="imagenet")


def test_n_shards_requires_sharded_backend():
    with pytest.raises(ValueError, match="n_shards"):
        _mini(backend="fleet", n_shards=2)
    cfg = _mini(backend="sharded_fleet", n_shards=1,
                cohort=CohortConfig(n_clients=3, n_data=240))
    assert cfg.n_shards == 1


def test_per_client_hyperparameters_flow_to_fleet():
    co = CohortConfig(n_clients=3, n_data=240, lr=[0.004, 0.01, 0.002],
                      local_epochs=[1, 2, 1])
    sim = build_simulation(_mini(backend="fleet", cohort=co))
    assert [c.lr for c in sim.clients] == [0.004, 0.01, 0.002]
    assert [c.local_epochs for c in sim.clients] == [1, 2, 1]
    eng = sim.server.engine
    np.testing.assert_allclose(eng.lrs, [0.004, 0.01, 0.002])
    sim.server.run_round()     # heterogeneous cohort executes


def test_per_client_length_mismatch_rejected():
    with pytest.raises(ValueError, match="lr"):
        CohortConfig(n_clients=3, lr=[0.1, 0.2]).client_lrs(0.01)
    with pytest.raises(ValueError, match="local_epochs"):
        CohortConfig(n_clients=2, local_epochs=[1, 2, 3]).client_epochs()


def test_policy_none_still_supported():
    sim = build_simulation(_mini(policy="none"))
    assert sim.server.cfg.method == "none"


def test_simulation_owns_store():
    """Every simulation carries a ClientStore slotting one client per id;
    set_speed writes through to it (tests/test_population.py covers the
    store itself)."""
    sim = build_simulation(_mini())
    assert sim.store.n_active == 3
    sim.set_speed(1, 99.0)
    assert float(sim.store.speeds_of([1])[0]) == 99.0
    assert sim.clients[1].speed == 99.0
