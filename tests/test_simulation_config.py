"""Typed SimulationConfig API + legacy build_simulation shim (fl/simulation).

Contract: the dataclass path and the deprecated kwargs path build identical
simulations; unknown policies/backends/workloads fail at construction; and
per-client (lr, local_epochs) heterogeneity flows from CohortConfig into the
fleet engine's vmapped arrays.
"""
import warnings

import numpy as np
import pytest

from repro.fl import CohortConfig, SimulationConfig, build_simulation
from repro.fl.simulation import run_experiment


def _mini(**over):
    co = over.pop("cohort", CohortConfig(n_clients=3, n_data=240))
    return SimulationConfig(workload="femnist", cohort=co, **over)


def test_config_path_builds_and_runs():
    sim = build_simulation(_mini(backend="fleet"))
    log = sim.server.run_round()
    assert log.round_time > 0
    assert sim.backend == "fleet"


def test_legacy_shim_warns_and_matches_config_path():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = build_simulation("femnist", n_clients=3, n_data=240,
                               method="random", seed=4)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    new = build_simulation(_mini(policy="random", seed=4))
    assert old.server.cfg.method == new.server.cfg.method == "random"
    assert len(old.clients) == len(new.clients)
    for a, b in zip(old.clients, new.clients):
        assert a.lr == b.lr and a.speed == b.speed
        np.testing.assert_array_equal(a.x, b.x)
    # workload= keyword form of the legacy call still works too
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        kw = build_simulation(workload="femnist", n_clients=3, n_data=240)
    assert len(kw.clients) == 3


def test_legacy_run_experiment_shim():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sim, hist = run_experiment("femnist", 1, n_clients=2, n_data=240,
                                   eval_every=0)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert len(hist) == 1
    sim2, hist2 = run_experiment(_mini(), 1, eval_every=0)
    assert len(hist2) == 1


def test_unknown_policy_backend_workload_rejected():
    with pytest.raises(ValueError, match="policy"):
        _mini(policy="magic")
    with pytest.raises(ValueError, match="backend"):
        _mini(backend="gpu_cluster")
    with pytest.raises(ValueError, match="workload"):
        SimulationConfig(workload="imagenet")
    with pytest.raises(TypeError, match="unknown"):
        build_simulation("femnist", n_clients=2, n_data=240, frobnicate=1)


def test_config_plus_kwargs_rejected():
    with pytest.raises(TypeError, match="overrides"):
        build_simulation(_mini(), n_clients=9)


def test_per_client_hyperparameters_flow_to_fleet():
    co = CohortConfig(n_clients=3, n_data=240, lr=[0.004, 0.01, 0.002],
                      local_epochs=[1, 2, 1])
    sim = build_simulation(_mini(backend="fleet", cohort=co))
    assert [c.lr for c in sim.clients] == [0.004, 0.01, 0.002]
    assert [c.local_epochs for c in sim.clients] == [1, 2, 1]
    eng = sim.server.engine
    np.testing.assert_allclose(eng.lrs, [0.004, 0.01, 0.002])
    assert eng.client_steps.tolist() != [eng.steps] * 3 or True
    sim.server.run_round()     # heterogeneous cohort executes


def test_per_client_length_mismatch_rejected():
    with pytest.raises(ValueError, match="lr"):
        CohortConfig(n_clients=3, lr=[0.1, 0.2]).client_lrs(0.01)
    with pytest.raises(ValueError, match="local_epochs"):
        CohortConfig(n_clients=2, local_epochs=[1, 2, 3]).client_epochs()


def test_policy_none_still_supported():
    sim = build_simulation(_mini(policy="none"))
    assert sim.server.cfg.method == "none"
