"""Decode-vs-full-forward consistency: running prefill then decode steps must
reproduce the logits of a single full forward (fp32 to isolate numerics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

ARCHS = ["stablelm-12b", "minicpm3-4b", "rwkv6-3b", "recurrentgemma-9b",
         "command-r-35b", "seamless-m4t-large-v2"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full(arch):
    cfg = (get_config(arch).smoke()
           .with_overrides(dtype="float32", param_dtype="float32"))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S, T = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + T), 0, 100)
    frames = (jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
              if cfg.is_encdec else None)

    full_in = {"tokens": toks}
    if frames is not None:
        full_in["frames"] = frames
    full_logits, _, _ = M.forward_seq(params, cfg, full_in)

    pre_in = {"tokens": toks[:, :S]}
    if frames is not None:
        pre_in["frames"] = frames
    logits, caches, _ = M.forward_seq(params, cfg, pre_in, want_cache=True,
                                      cache_len=S + T)
    np.testing.assert_allclose(logits[:, -1], full_logits[:, S - 1],
                               rtol=2e-3, atol=2e-3)

    for t in range(T):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, caches = M.decode_step(params, cfg, caches,
                                   toks[:, S + t][:, None], pos)
        np.testing.assert_allclose(
            lg[:, 0], full_logits[:, S + t], rtol=5e-3, atol=5e-3,
            err_msg=f"{arch} decode step {t}")
