"""Masked FedAvg aggregation."""
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import ClientUpdate, aggregate


def test_plain_fedavg():
    p = {"w": jnp.zeros((2, 2))}
    u1 = ClientUpdate({"w": jnp.ones((2, 2))}, n_samples=1, client_id=0)
    u2 = ClientUpdate({"w": 3 * jnp.ones((2, 2))}, n_samples=3, client_id=1)
    out = aggregate(p, [u1, u2])
    np.testing.assert_allclose(out["w"], (1 * 1 + 3 * 3) / 4 * np.ones((2, 2)))


def test_masked_elements_use_partial_denominator():
    p = {"w": jnp.zeros((2,))}
    full = ClientUpdate({"w": jnp.array([1.0, 1.0])}, 1, None, client_id=0)
    mask = {"w": jnp.array([1.0, 0.0])}
    sub = ClientUpdate({"w": jnp.array([3.0, 999.0])}, 1, mask, client_id=1)
    out = aggregate(p, [full, sub])
    # element 0: (1+3)/2 ; element 1: only the full client contributes
    np.testing.assert_allclose(out["w"], [2.0, 1.0])


def test_all_masked_element_unchanged():
    p = {"w": jnp.array([7.0])}
    mask = {"w": jnp.array([0.0])}
    sub = ClientUpdate({"w": jnp.array([5.0])}, 2, mask, client_id=0)
    out = aggregate(p, [sub])
    np.testing.assert_allclose(out["w"], [7.0])
