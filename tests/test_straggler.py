"""Straggler detection + linear-time sub-model sizing (paper §5)."""
from repro.core import straggler as sg


def test_detect_by_frac():
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat, frac=0.2) == [0]


def test_detect_auto_gap():
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat) == [0]
    lat2 = {0: 10.3, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat2) == []


def test_plan_picks_inverse_speedup():
    lat = {0: 13.0, 1: 10.0, 2: 9.8}
    plan = sg.plan(lat, frac=None)
    assert plan.stragglers == [0]
    assert plan.t_target == 10.0
    # speedup 1.3 -> 1/1.3 = 0.77 -> nearest predefined size 0.75
    assert plan.rates[0] == 0.75


def test_pick_rate_bounds():
    assert sg.pick_rate(1.0) == 0.95
    assert sg.pick_rate(2.5) == 0.5
