"""Straggler detection + linear-time sub-model sizing (paper §5).

Includes recalibration-under-drift properties: latencies crossing the gap
threshold flip membership (and hence the assigned dropout rate) on the
very next plan — the one-calibration-interval adaptation the paper's
Fig. 4b claims. Property checks are seeded numpy sweeps (hypothesis is
not available in the container)."""
import numpy as np

from repro.core import straggler as sg
from repro.fl.population import ClientStore


def test_detect_by_frac():
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat, frac=0.2) == [0]


def test_detect_frac_zero_selects_nobody():
    """Regression: frac=0.0 used to flag the slowest client anyway via the
    unconditional max(1, ...) floor, so "dropout off" configs silently ran
    dropout on one client per round."""
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat, frac=0.0) == []
    p = sg.plan(lat, frac=0.0)
    assert p.stragglers == [] and p.rates == {}
    # any positive frac still selects at least one
    assert sg.detect_stragglers(lat, frac=1e-9) == [0]
    assert sg.detect_stragglers(lat, frac=1.0) == [0, 2, 4, 1, 3]


def test_detect_frac_out_of_range_raises():
    lat = {0: 13.0, 1: 10.0}
    for bad in (-0.1, 1.5, 2.0):
        try:
            sg.detect_stragglers(lat, frac=bad)
        except ValueError as e:
            assert "frac" in str(e)
        else:
            raise AssertionError(f"frac={bad} was accepted")


def test_detect_auto_gap():
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat) == [0]
    lat2 = {0: 10.3, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    assert sg.detect_stragglers(lat2) == []


def test_plan_picks_inverse_speedup():
    lat = {0: 13.0, 1: 10.0, 2: 9.8}
    plan = sg.plan(lat, frac=None)
    assert plan.stragglers == [0]
    assert plan.t_target == 10.0
    # speedup 1.3 -> 1/1.3 = 0.77 -> nearest predefined size 0.75
    assert plan.rates[0] == 0.75


def test_detect_tied_straggler_band():
    """Population cohorts hold many stragglers at the SAME slow speed; the
    gap split must see past the ties to the band/cluster boundary."""
    lat = {i: 13.0 for i in range(5)}
    lat.update({i: 10.0 + 0.01 * i for i in range(5, 40)})
    assert sorted(sg.detect_stragglers(lat)) == [0, 1, 2, 3, 4]
    plan = sg.plan(lat)
    assert sorted(plan.stragglers) == [0, 1, 2, 3, 4]
    assert all(plan.rates[c] < 1.0 for c in range(5))
    # an all-tied cohort has no gap, hence no stragglers
    assert sg.detect_stragglers({i: 10.0 for i in range(6)}) == []


def test_detect_gapped_chain():
    """Consecutively-gapped slow clients are all in the band (the largest
    gap is the one separating them from the cluster)."""
    lat = {0: 13.0, 1: 11.5, 2: 10.0, 3: 9.95}
    assert sg.detect_stragglers(lat) == [0, 1]


def test_detect_band_survives_noise_filled_gaps():
    """Population-scale property: once a cohort has thousands of noisy
    draws, the slow band's fastest draw and the cluster's slowest draw
    touch — adjacent-gap detection goes blind, the density-dip split
    (plan_from_store's rule) still recovers the band exactly."""
    rng = np.random.RandomState(0)
    n, frac = 4000, 0.1
    slow = rng.rand(n) < frac
    speed = np.where(slow, 13.0, 10.0 * (1 + 0.05 * np.clip(
        rng.randn(n), -2.5, 2.5)))
    lat = {i: float(speed[i] * (1 + 0.03 * rng.randn())) for i in range(n)}
    ordered = np.sort(list(lat.values()))
    # the premise: no 1.10 adjacent gap survives at this cohort size
    assert (ordered[1:] / ordered[:-1]).max() < 1.10
    assert sg.detect_stragglers(lat) == []
    got = set(sg.detect_band(lat))
    want = set(np.flatnonzero(slow).tolist())
    # dip split recovers the band modulo clients whose draws landed inside
    # the other mode (boundary noise), which are individually ambiguous
    assert len(got ^ want) < 0.02 * n
    assert len(got & want) > 0.9 * len(want)


def test_detect_band_agrees_with_gap_when_separated():
    for lat in ({0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1},
                {0: 10.3, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1},
                {0: 13.0, 1: 10.0, 2: 9.8}):
        assert sg.detect_band(lat) == sg.detect_stragglers(lat)


def test_pick_rate_bounds():
    assert sg.pick_rate(1.0) == 0.95
    assert sg.pick_rate(2.5) == 0.5


# ---------------------------------------------------------------------------
# Recalibration under drift


def test_drift_flips_membership_next_plan():
    """A speed change crossing the gap threshold re-targets in ONE plan:
    no hysteresis, exactly the per-calibration-step rule of paper §5."""
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9}
    assert sg.plan(lat).stragglers == [0]
    lat[0], lat[2] = 10.1, 13.5            # 0 recovers, 2 degrades
    after = sg.plan(lat)
    assert after.stragglers == [2]
    assert 0 not in after.rates and after.rates[2] < 1.0


def test_threshold_crossing_is_sharp():
    """Property: scanning one client's latency across gap_factor * t_next
    flips membership exactly at the boundary, and its dropout rate tracks
    1/speedup monotonically (linear-time model, App A.3)."""
    base = {1: 10.0, 2: 10.2, 3: 9.9}
    prev_rate = 1.0
    for scale in np.linspace(1.0, 2.0, 21):
        lat = {0: 10.2 * float(scale), **base}
        plan = sg.plan(lat, gap_factor=1.10)
        if scale <= 1.10:                  # within the gap: no straggler
            assert plan.stragglers == []
        else:
            assert plan.stragglers == [0]
            rate = plan.rates[0]
            assert rate <= prev_rate       # slower => smaller sub-model
            prev_rate = rate
            assert rate == sg.pick_rate(lat[0] / 10.2)


def test_plan_properties_random_latencies():
    """Property sweep: for random latency draws, every plan satisfies the
    paper's invariants — stragglers are the slowest clients, t_target is
    the slowest NON-straggler, and rates are valid sub-model sizes < 1."""
    rng = np.random.RandomState(0)
    sizes = sg.DEFAULT_SIZES
    for _ in range(200):
        n = rng.randint(2, 12)
        lat = {i: float(10.0 * (1.0 + 0.3 * rng.rand()))
               for i in range(n)}
        if rng.rand() < 0.5:               # sometimes a clear straggler band
            for j in range(rng.randint(0, max(1, n // 3))):
                lat[j] *= 1.5
        plan = sg.plan(lat)
        non = [c for c in lat if c not in plan.stragglers]
        if plan.stragglers:
            assert plan.t_target == max(lat[c] for c in non)
            slowest_non = max(lat[c] for c in non)
            for c in plan.stragglers:
                assert lat[c] > slowest_non     # stragglers ARE the slow tail
                assert plan.rates[c] in sizes and plan.rates[c] < 1.0
                assert plan.speedups[c] == lat[c] / plan.t_target


def test_store_backed_drift_flips_within_one_interval():
    """plan_from_store sees drift as soon as the round that observed it is
    recorded — membership and rates flip within one calibration interval."""
    ids = [0, 1, 2, 3]
    st = ClientStore.empty(8).register(ids, np.full(4, 10.0), np.zeros(4))
    st = st.update_from_round(ids, [13.0, 10.0, 10.2, 9.9], np.ones(4))
    assert sg.plan_from_store(st, ids).stragglers == [0]
    # next round's observations cross the threshold the other way
    st = st.update_from_round(ids, [10.0, 10.1, 13.4, 10.0], np.ones(4))
    after = sg.plan_from_store(st, ids)
    assert after.stragglers == [2]
    assert after.rates[2] < 1.0 and 0 not in after.rates
