"""Random / Ordered / Invariant selection policies."""
import numpy as np
import pytest

from repro.core.dropout import (DropoutPolicy, invariant_keep, keep_count,
                                ordered_keep, random_keep)

SPECS = [{"name": "a", "size": 10, "out": [], "in": []},
         {"name": "b", "size": 20, "out": [], "in": []}]


def test_keep_count():
    assert keep_count(10, 0.75) == 8
    assert keep_count(10, 0.05) == 1          # never empty


def test_ordered_is_prefix():
    np.testing.assert_array_equal(ordered_keep(10, 0.5), np.arange(5))


def test_random_unique_sorted():
    rng = np.random.RandomState(0)
    k = random_keep(rng, 100, 0.65)
    assert len(k) == 65 == len(set(k.tolist()))
    assert np.all(np.diff(k) > 0)


def test_invariant_drops_most_voted():
    votes = np.array([5, 0, 5, 0, 5, 0, 0, 0, 0, 0])
    stats = np.linspace(0.1, 1.0, 10)
    keep = invariant_keep(votes, stats, r=0.7)      # drop 3
    assert set([0, 2, 4]).isdisjoint(keep)
    assert len(keep) == 7


def test_invariant_tiebreak_by_stat():
    votes = np.zeros(10)
    stats = np.array([9, 1, 8, 2, 7, 3, 6, 4, 5, 0], float)
    keep = invariant_keep(votes, stats, r=0.8)      # drop 2 smallest stats
    assert 9 not in keep and 1 not in keep and 0 in keep
    assert len(keep) == 8


def test_policy_full_model_identity():
    pol = DropoutPolicy("random", SPECS)
    km = pol.keep_map(1.0)
    assert all(len(km[g["name"]]) == g["size"] for g in SPECS)


def test_policy_invariant_fallback_ordered():
    pol = DropoutPolicy("invariant", SPECS)
    km = pol.keep_map(0.5)          # no stats observed yet
    np.testing.assert_array_equal(km["a"], np.arange(5))
