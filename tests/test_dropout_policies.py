"""Random / Ordered / Invariant selection policies."""
import numpy as np
import pytest

from repro.core.dropout import (DropoutPolicy, invariant_keep, keep_count,
                                ordered_keep, random_keep)

SPECS = [{"name": "a", "size": 10, "out": [], "in": []},
         {"name": "b", "size": 20, "out": [], "in": []}]


def test_keep_count():
    assert keep_count(10, 0.75) == 8
    assert keep_count(10, 0.05) == 1          # never empty


def test_ordered_is_prefix():
    np.testing.assert_array_equal(ordered_keep(10, 0.5), np.arange(5))


def test_random_unique_sorted():
    rng = np.random.RandomState(0)
    k = random_keep(rng, 100, 0.65)
    assert len(k) == 65 == len(set(k.tolist()))
    assert np.all(np.diff(k) > 0)


def test_invariant_drops_most_voted():
    votes = np.array([5, 0, 5, 0, 5, 0, 0, 0, 0, 0])
    stats = np.linspace(0.1, 1.0, 10)
    keep = invariant_keep(votes, stats, r=0.7)      # drop 3
    assert set([0, 2, 4]).isdisjoint(keep)
    assert len(keep) == 7


def test_invariant_tiebreak_by_stat():
    votes = np.zeros(10)
    stats = np.array([9, 1, 8, 2, 7, 3, 6, 4, 5, 0], float)
    keep = invariant_keep(votes, stats, r=0.8)      # drop 2 smallest stats
    assert 9 not in keep and 1 not in keep and 0 in keep
    assert len(keep) == 8


def test_policy_full_model_identity():
    pol = DropoutPolicy("random", SPECS)
    km = pol.keep_map(1.0)
    assert all(len(km[g["name"]]) == g["size"] for g in SPECS)


def test_policy_invariant_fallback_ordered():
    pol = DropoutPolicy("invariant", SPECS)
    km = pol.keep_map(0.5)          # no stats observed yet
    np.testing.assert_array_equal(km["a"], np.arange(5))


# ---------------------------------------------------------------------------
# policy registry (get_policy / register_policy)

def test_registry_resolves_all_builtins():
    from repro.core.dropout import available_policies, get_policy
    assert available_policies() == ("invariant", "ordered", "random")
    for name in available_policies():
        pol = get_policy(name, SPECS, seed=1)
        assert pol.method == name
        assert len(pol.keep_map(0.5)["a"]) == 5


def test_registry_unknown_name_lists_choices():
    from repro.core.dropout import get_policy
    with pytest.raises(ValueError, match="invariant"):
        get_policy("magic", SPECS)


def test_registry_filters_foreign_kwargs():
    from repro.core.dropout import get_policy
    pol = get_policy("ordered", SPECS, ema_decay=0.9)   # not a field: dropped
    assert not hasattr(pol, "ema_decay") or pol.method == "ordered"
    inv = get_policy("invariant", SPECS, ema_decay=0.9)
    assert inv.ema_decay == 0.9


def test_register_policy_plugs_into_table():
    from repro.core import dropout as dd

    @dd.register_policy("_test_tail")
    @dd.dataclasses.dataclass
    class TailPolicy(dd.BasePolicy):
        def keep(self, name, size, r):
            return np.arange(size - keep_count(size, r), size)
    try:
        pol = dd.get_policy("_test_tail", SPECS)
        np.testing.assert_array_equal(pol.keep_map(0.5)["a"],
                                      np.arange(5, 10))
        assert "_test_tail" in dd.available_policies()
    finally:
        del dd._REGISTRY["_test_tail"]


def test_dropout_policy_alias_back_compat():
    from repro.core.dropout import BasePolicy
    pol = DropoutPolicy("ordered", SPECS, seed=2)
    assert isinstance(pol, BasePolicy) and pol.method == "ordered"
