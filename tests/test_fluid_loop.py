"""FLuID server loop end-to-end on the paper workloads (small scale)."""
import numpy as np
import pytest

from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation)

pytestmark = pytest.mark.slow    # multi-minute: tier-1 only, not the CI fast tier


def _cfg(method="invariant", n_clients=5, seed=0):
    return SimulationConfig(
        workload="femnist", policy=method, seed=seed,
        cohort=CohortConfig(n_clients=n_clients, straggler_ids=(0,),
                            n_data=400))


@pytest.fixture(scope="module")
def sim_hist():
    sim = build_simulation(_cfg())
    hist = sim.server.run(6, eval_every=6)
    return sim, hist


def test_straggler_detected_and_rate_assigned(sim_hist):
    sim, hist = sim_hist
    assert hist[-1].stragglers == [0]
    assert 0 < hist[-1].rates[0] < 1.0


def test_straggler_time_near_target(sim_hist):
    """Paper Fig 4a: after FLuID the straggler lands within ~10% of
    T_target."""
    sim, hist = sim_hist
    late = [h for h in hist if h.stragglers and h.straggler_time > 0]
    assert late
    h = late[-1]
    assert h.straggler_time <= 1.15 * h.t_target


def test_round_time_improves_vs_no_dropout():
    times = {}
    for method in ("none", "invariant"):
        sim = build_simulation(_cfg(method=method))
        hist = sim.server.run(5)
        times[method] = np.mean([h.round_time for h in hist[2:]])
    assert times["invariant"] < times["none"]


def test_invariant_fraction_grows(sim_hist):
    sim, hist = sim_hist
    fr = [h.invariant_frac for h in hist if h.invariant_frac > 0]
    assert fr and fr[-1] > 0.0


def test_dynamic_straggler_recalibration():
    """Paper Fig 4b: when the slow device changes, FLuID re-targets."""
    sim = build_simulation(_cfg(n_clients=4, seed=1))
    sim.server.run(3)
    assert sim.server.plan.stragglers == [0]
    sim.set_speed(0, 10.0)      # straggler recovers
    sim.set_speed(2, 14.0)      # a different client degrades
    sim.server.run(3)
    assert sim.server.plan.stragglers == [2]
