"""Recurrent-layer numerics: chunked RWKV-6 vs naive recurrence; RG-LRU
associative scan vs step-by-step decode; state continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [dev] extra")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import rglru as rg
from repro.models import rwkv6 as rw


def _cfg(chunk=8):
    return (get_config("rwkv6-3b").smoke()
            .with_overrides(dtype="float32", param_dtype="float32",
                            rwkv_chunk=chunk))


def test_chunked_matches_naive():
    cfg = _cfg()
    p = rw.init_tmix(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    y1, last1, s1 = rw.tmix_seq(p, x, cfg)
    y2, last2, s2 = rw.tmix_ref(p, x, cfg)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([2, 4, 6, 8, 24]), seed=st.integers(0, 100))
def test_chunk_size_invariance(chunk, seed):
    cfg = _cfg(chunk)
    p = rw.init_tmix(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 24, cfg.d_model))
    y, _, s = rw.tmix_seq(p, x, cfg)
    y_ref, _, s_ref = rw.tmix_ref(p, x, cfg)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_decode_continues_seq():
    cfg = _cfg()
    p = rw.init_tmix(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, cfg.d_model))
    y_full, _, _ = rw.tmix_ref(p, x, cfg)
    y_pre, last, state = rw.tmix_seq(p, x[:, :16], cfg)
    y_dec, _, _ = rw.tmix_decode(p, x[:, 16:17], cfg, last, state)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 16], rtol=1e-4,
                               atol=1e-4)


def test_rglru_decode_matches_seq():
    cfg = (get_config("recurrentgemma-9b").smoke()
           .with_overrides(dtype="float32", param_dtype="float32"))
    p = rg.init_rglru(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y_full, st_full = rg.rglru_seq(p, x, cfg)
    y_pre, st_pre = rg.rglru_seq(p, x[:, :8], cfg)
    y_dec, st_dec = rg.rglru_decode(p, x[:, 8:9], cfg, st_pre)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 8], rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(st_dec["h"], st_full["h"], rtol=1e-4,
                               atol=1e-4)


def test_rglru_decay_bounded():
    cfg = (get_config("recurrentgemma-9b").smoke()
           .with_overrides(dtype="float32", param_dtype="float32"))
    p = rg.init_rglru(jax.random.PRNGKey(0), cfg)
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y, st = rg.rglru_seq(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(st["h"]).all())
