"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (decode_gqa, invariant_stats, masked_ffn,
                               neuron_mask_to_block_mask)


@pytest.mark.parametrize("shape", [(64, 128), (300, 200), (1024, 96),
                                   (17, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_invariant_stats_sweep(shape, dtype):
    k = jax.random.PRNGKey(0)
    w0 = jax.random.normal(k, shape).astype(dtype)
    w1 = (w0.astype(jnp.float32)
          + 0.02 * jax.random.normal(jax.random.fold_in(k, 1), shape)
          ).astype(dtype)
    got = invariant_stats(w0, w1)
    want = ref.invariant_stats_ref(w0, w1)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("M,d,F", [(100, 256, 512), (64, 128, 128),
                                   (257, 64, 384)])
@pytest.mark.parametrize("act,gated", [("silu", True), ("gelu", False),
                                       ("relu2", False)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_ffn_sweep(M, d, F, act, gated, dtype):
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (M, d)).astype(dtype)
    win = (0.05 * jax.random.normal(jax.random.fold_in(k, 1), (d, F))
           ).astype(dtype)
    wout = (0.05 * jax.random.normal(jax.random.fold_in(k, 2), (F, d))
            ).astype(dtype)
    wg = ((0.05 * jax.random.normal(jax.random.fold_in(k, 3), (d, F))
           ).astype(dtype) if gated else None)
    rng = np.random.RandomState(M + F)
    mask = jnp.asarray(rng.randint(0, 2, F // 128).astype(np.int32))
    got = masked_ffn(x, win, wout, mask, w_gate=wg, act=act)
    want = ref.masked_ffn_ref(x, win, wout, mask, w_gate=wg, act=act)
    tol = 2e-3 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), rtol=tol, atol=tol)


def test_masked_ffn_all_dropped_is_zero():
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (32, 64))
    win = jax.random.normal(jax.random.fold_in(k, 1), (64, 256))
    wout = jax.random.normal(jax.random.fold_in(k, 2), (256, 64))
    y = masked_ffn(x, win, wout, jnp.zeros(2, jnp.int32), act="gelu")
    np.testing.assert_allclose(y, 0.0, atol=1e-6)


@pytest.mark.parametrize("B,H,KV,hd,C", [(2, 8, 2, 64, 512),
                                         (1, 4, 4, 128, 300),
                                         (3, 16, 1, 64, 1024)])
def test_decode_gqa_sweep(B, H, KV, hd, C):
    k = jax.random.PRNGKey(4)
    q = jax.random.normal(k, (B, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(k, 1), (B, C, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(k, 2), (B, C, KV, hd))
    lengths = jnp.asarray(
        np.random.RandomState(B).randint(1, C + 1, (B,)), jnp.int32)
    got = decode_gqa(q, kc, vc, lengths, block_c=128)
    want = ref.decode_gqa_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


import itertools


@pytest.mark.parametrize("B,S,H,N,chunk", [(2, 32, 3, 16, 8),
                                           (1, 24, 2, 32, 12),
                                           (3, 16, 1, 64, 16)])
def test_rwkv_chunk_scan_sweep(B, S, H, N, chunk):
    from repro.kernels.ops import rwkv_chunk_scan
    key = jax.random.PRNGKey(7)
    r = jax.random.normal(key, (B, S, H, N))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, N))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, N))
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                      (B, S, H, N)) - 1.0)
    u = 0.3 * jax.random.normal(jax.random.fold_in(key, 4), (H, N))
    y, st = rwkv_chunk_scan(r, kk, v, logw, u, chunk=chunk)
    yr, sr = ref.rwkv_chunk_scan_ref(r, kk, v, logw, u)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st, sr, rtol=2e-4, atol=2e-4)


def test_rwkv_chunk_strong_decay_no_overflow():
    from repro.kernels.ops import rwkv_chunk_scan
    key = jax.random.PRNGKey(8)
    B, S, H, N = 1, 32, 1, 16
    r = jax.random.normal(key, (B, S, H, N))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, N))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, N))
    logw = jnp.full((B, S, H, N), -8.0)     # near-total decay
    u = jnp.zeros((H, N))
    y, st = rwkv_chunk_scan(r, kk, v, logw, u, chunk=16)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(st).all())


def test_block_mask_conversion():
    m = np.zeros(256)
    m[5] = 1            # one surviving neuron keeps its block
    np.testing.assert_array_equal(neuron_mask_to_block_mask(m), [1, 0])
