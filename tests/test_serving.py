"""Personalized sub-model serving engine (launch/serving.py).

Acceptance contract:
  * Mask-as-data decode parity — the engine's masked decode reproduces, token
    for token, a dense forward over params with the sub-model baked into the
    weights (zeroed in-columns / out-rows), in float32.
  * One compiled program — a queue mixing >= 3 distinct dropout rates
    (including 0.0 dropout = full model), ragged prompt lengths, and ragged
    generation lengths drains with each jitted body traced exactly once.
  * The Pallas serving kernels (interpret mode on CPU) plug into the same
    decode step without changing greedy outputs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serving import (ServeEngine, ServeRequest,
                                  apply_masks_to_params, mask_fingerprint,
                                  rate_masks)
from repro.models import model as model_lib

jax.config.update("jax_platform_name", "cpu")


def _cfg(arch="stablelm-12b", **over):
    cfg = get_config(arch).smoke()
    over.setdefault("dtype", "float32")     # exact parity checks
    return dataclasses.replace(cfg, **over)


def _params(cfg, seed=0):
    return model_lib.init_params(cfg, jax.random.PRNGKey(seed))


def _prompt(cfg, L, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randint(0, min(cfg.vocab_size, 256), (L,), dtype=np.int32)


def _dense_reference(cfg, params, prompt, gen_len):
    """Greedy generation via full-sequence re-forward each step — the
    slowest, most obviously correct decoder."""
    import jax.numpy as jnp
    toks = list(np.asarray(prompt, np.int32))
    out = []
    for _ in range(gen_len):
        logits, _, _ = model_lib.forward_seq(
            params, cfg, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return np.asarray(out, np.int32)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    return cfg, _params(cfg)


def test_masked_decode_parity_token_for_token(setup):
    """Engine decode with mask-as-data == dense decode of the physically
    masked weights, for r in {1.0, 0.5, 0.25}."""
    cfg, params = setup
    prompt = _prompt(cfg, 8)
    gen = 8
    for r in (1.0, 0.5, 0.25):
        masks = None if r >= 1.0 else rate_masks(cfg, r, policy="random",
                                                 seed=3)
        eng = ServeEngine(cfg, params, batch_size=2, max_prompt_len=8,
                          max_gen_len=gen, chunk=4)
        rid = eng.submit(ServeRequest(prompt, gen_len=gen, masks=masks))
        got = eng.run()[rid]
        ref_params = (params if masks is None
                      else apply_masks_to_params(params, masks, cfg))
        want = _dense_reference(cfg, ref_params, prompt, gen)
        np.testing.assert_array_equal(got, want), r


def test_mixed_rate_queue_single_compilation(setup):
    """>= 3 distinct rates (incl. full model), ragged prompts and gen
    lengths, more requests than slots: drains correctly with exactly one
    trace of prefill / insert / decode."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_size=3, max_prompt_len=8,
                      max_gen_len=8, chunk=4, bank_size=6)
    rates = [1.0, 0.5, 0.25, 0.75, 1.0, 0.5, 0.25]
    lens = [8, 5, 7, 3, 8, 6, 4]
    gens = [8, 3, 6, 1, 5, 8, 2]
    reqs = {}
    for i, (r, L, g) in enumerate(zip(rates, lens, gens)):
        masks = None if r >= 1.0 else rate_masks(cfg, r, seed=0)
        prompt = _prompt(cfg, L, seed=i)
        rid = eng.submit(ServeRequest(prompt, gen_len=g, masks=masks))
        reqs[rid] = (prompt, g, masks)
    results = eng.run()
    assert set(results) == set(reqs)
    for body in ("prefill", "insert", "decode"):
        assert eng.trace_counts[body] == 1, (body, eng.trace_counts)
    # every request's tokens match its own personalized dense reference
    for rid, (prompt, g, masks) in reqs.items():
        ref_params = (params if masks is None
                      else apply_masks_to_params(params, masks, cfg))
        want = _dense_reference(cfg, ref_params, prompt, g)
        np.testing.assert_array_equal(results[rid], want), rid
    assert eng.summary()["tok_per_s"] > 0


def test_mask_bank_dedupe_and_eviction(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_size=2, max_prompt_len=4,
                      max_gen_len=4, bank_size=3)
    m1 = rate_masks(cfg, 0.5, seed=0)
    m1_dup = jax.tree.map(lambda x: x + 0, m1)     # equal values, new arrays
    m2 = rate_masks(cfg, 0.25, seed=0)
    m3 = rate_masks(cfg, 0.75, seed=0)
    assert mask_fingerprint(m1) == mask_fingerprint(m1_dup)
    for m in (m1, m1_dup, m2, m3, None):
        eng.submit(ServeRequest(_prompt(cfg, 4), gen_len=2, masks=m))
    results = eng.run()
    assert len(results) == 5
    # capacity 3 (ones + 2): m3 must have evicted a dead row, not grown K
    assert jax.tree.leaves(eng.bank.stacked())[0].shape[0] == 3
    assert eng.trace_counts["decode"] == 1


def test_prompt_and_gen_length_validation(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_size=1, max_prompt_len=4,
                      max_gen_len=4)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(ServeRequest(_prompt(cfg, 6), gen_len=2))
    with pytest.raises(ValueError, match="gen_len"):
        eng.submit(ServeRequest(_prompt(cfg, 3), gen_len=9))


def test_encdec_rejected():
    cfg = get_config("seamless-m4t-large-v2").smoke()
    with pytest.raises(NotImplementedError):
        ServeEngine(cfg, None)


def test_recurrent_arch_requires_exact_length_prompts():
    cfg = _cfg("rwkv6-3b")
    params = _params(cfg)
    eng = ServeEngine(cfg, params, batch_size=1, max_prompt_len=6,
                      max_gen_len=4)
    assert eng.recurrent
    with pytest.raises(ValueError, match="exactly"):
        eng.submit(ServeRequest(_prompt(cfg, 3), gen_len=2))
    rid = eng.submit(ServeRequest(_prompt(cfg, 6), gen_len=4))
    out = eng.run()[rid]
    np.testing.assert_array_equal(
        out, _dense_reference(cfg, params, _prompt(cfg, 6), 4))


@pytest.mark.parametrize("kernels", [
    {"ffn": True, "attn": False, "interpret": True},
    {"ffn": False, "attn": True, "interpret": True},
])
def test_pallas_kernels_match_jnp_decode(setup, kernels):
    """Serving kernels (interpret mode) slot into the decode step without
    changing greedy outputs."""
    cfg, params = setup
    prompt = _prompt(cfg, 6)
    masks = rate_masks(cfg, 0.5, seed=1)

    def run(kern):
        eng = ServeEngine(cfg, params, batch_size=2, max_prompt_len=6,
                          max_gen_len=4, chunk=4, kernels=kern)
        rid = eng.submit(ServeRequest(prompt, gen_len=4, masks=masks))
        rid2 = eng.submit(ServeRequest(prompt, gen_len=4))
        out = eng.run()
        return out[rid], out[rid2]
    a = run(None)
    b = run(kernels)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
