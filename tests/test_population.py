"""Population layer: ClientStore ops, sharded cohort execution, round
pipeline equivalence (fl/population.py, fl/rounds.py, fl/shard_fleet.py).

The acceptance contracts:
  * all three RoundBackends produce the same round decisions and agree on
    aggregated params up to float summation order, for cohorts sampled
    from a 10^4-client store;
  * with >= 2 host devices, the sharded_fleet run on a 2-device mesh is
    BITWISE identical to the same run on a 1-device mesh — cohort samples
    and aggregated params (the S-shard program is the numerical contract,
    the device count is not);
  * straggler recalibration reads the store's history
    (core/straggler.plan_from_store) and reacts to drift within one
    calibration interval.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import straggler as sg
from repro.fl.population import (ClientStore, PopulationConfig,
                                 build_population, population_speeds)

jax.config.update("jax_platform_name", "cpu")


def _pop_cfg(**over):
    kw = dict(n_clients=10_000, cohort_size=8, workload="synth",
              backend="fleet", n_partitions=16, samples_per_partition=40,
              seed=42)
    kw.update(over)
    return PopulationConfig(**kw)


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _tree_close(a, b, atol):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), atol=atol, rtol=1e-5), a, b)


# ---------------------------------------------------------------------------
# ClientStore unit behaviour


def test_store_register_and_views():
    st = ClientStore.empty(100).register([3, 7], [10.0, 13.0], [1, 2])
    assert st.capacity == 100 and st.n_active == 2
    assert st.speeds_of([3, 7]).tolist() == [10.0, 13.0]
    assert st.shards_of([7]).tolist() == [2]
    assert st.rates_of([3]).tolist() == [1.0]     # full model by default


def test_store_sample_cohort_deterministic_and_active_only():
    st = ClientStore.empty(50).register(np.arange(0, 50, 2),
                                        np.full(25, 10.0), np.zeros(25))
    key = jax.random.PRNGKey(0)
    ids = np.asarray(st.sample_cohort(key, 10))
    again = np.asarray(st.sample_cohort(key, 10))
    np.testing.assert_array_equal(ids, again)          # same key, same cohort
    assert np.all(ids % 2 == 0)                        # only active slots
    assert np.all(np.diff(ids) > 0)                    # sorted, no repeats
    other = np.asarray(st.sample_cohort(jax.random.PRNGKey(1), 10))
    assert not np.array_equal(ids, other)              # keys decorrelate


def test_store_sample_cohort_oversized_request_raises():
    """Regression: asking for more clients than are active used to hand
    back inactive slots silently — top_k pads the Gumbel scores' -inf tail
    with whatever indices it likes, and downstream code materialized them
    as zero-speed phantom clients."""
    st = ClientStore.empty(50).register(np.arange(0, 50, 2),
                                        np.full(25, 10.0), np.zeros(25))
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="requested 26 .* only 25"):
        st.sample_cohort(key, 26)
    # exactly the active count is fine, and stays active-only
    ids = np.asarray(st.sample_cohort(key, 25))
    assert np.all(ids % 2 == 0)
    # in-flight clients shrink the *available* pool, not the active one
    st2 = st.mark_in_flight([0, 2], True)
    with pytest.raises(ValueError, match="only 23 are available"):
        st2.sample_cohort(key, 24, available_only=True)
    assert len(np.asarray(st2.sample_cohort(key, 25))) == 25


def test_store_update_from_round_ring_and_ema():
    st = ClientStore.empty(10, history=3).register([0, 1], [10.0, 13.0],
                                                   [0, 0])
    st = st.update_from_round([0, 1], [10.0, 13.0], [1.0, 0.75])
    # first observation seeds the EMAs directly
    assert float(st.speed_ema[0]) == 10.0
    assert float(st.straggler_ema[1]) == 1.0           # trained a sub-model
    assert float(st.straggler_ema[0]) == 0.0
    np.testing.assert_allclose(st.last_latency([0, 1]), [10.0, 13.0])
    assert np.isnan(st.last_latency([5])[0])           # never observed
    # ring buffer wraps at `history` without losing the newest value
    for t in (11.0, 12.0, 14.0):
        st = st.update_from_round([0], [t], [1.0])
    assert int(st.rounds_participated[0]) == 4
    assert float(st.last_latency([0])[0]) == 14.0
    assert np.isfinite(np.asarray(st.speed_hist)[0]).all()


def test_store_assign_rates_and_set_speed():
    st = ClientStore.empty(8).register(np.arange(8), np.full(8, 10.0),
                                       np.zeros(8))
    st = st.assign_rates([2, 5], [0.75, 0.85])
    np.testing.assert_allclose(st.rates_of([2, 5, 0]), [0.75, 0.85, 1.0])
    st = st.set_speed([2], [13.0])
    assert float(st.speeds_of([2])[0]) == 13.0


def test_store_is_a_pytree():
    st = ClientStore.empty(4).register([0, 1], [1.0, 2.0], [0, 1])
    leaves, treedef = jax.tree.flatten(st)
    st2 = jax.tree.unflatten(treedef, leaves)
    assert _leaves_equal(st, st2)
    doubled = jax.jit(lambda s: s.assign_rates([0], [0.5]))(st)
    assert float(doubled.dropout_rate[0]) == 0.5


def test_population_speeds_shape_and_band():
    sp = population_speeds(1000, straggler_frac=0.1, seed=0)
    assert sp.shape == (1000,) and sp.dtype == np.float32
    slow = sp == np.float32(13.0)
    # ~10% slow band, fast cluster clearly below it (gap stays well-posed)
    assert 50 < slow.sum() < 200
    assert sp[~slow].max() < 12.0


# ---------------------------------------------------------------------------
# plan_from_store == plan on equal observations


def test_plan_from_store_matches_plan():
    st = ClientStore.empty(10).register(np.arange(5), np.full(5, 10.0),
                                        np.zeros(5))
    lat = {0: 13.0, 1: 10.0, 2: 10.2, 3: 9.9, 4: 10.1}
    st = st.update_from_round(list(lat), list(lat.values()), np.ones(5))
    got = sg.plan_from_store(st, list(lat))
    want = sg.plan(lat)
    assert got.stragglers == want.stragglers == [0]
    # store observations round-trip through f32; decisions are identical
    assert got.t_target == pytest.approx(want.t_target, rel=1e-6)
    assert got.rates == want.rates


def test_plan_from_store_skips_unobserved():
    st = ClientStore.empty(10).register(np.arange(6), np.full(6, 10.0),
                                        np.zeros(6))
    st = st.update_from_round([0, 1, 2], [13.0, 10.0, 10.1], np.ones(3))
    plan = sg.plan_from_store(st, [0, 1, 2, 5])     # 5 never participated
    assert plan.stragglers == [0]
    empty = sg.plan_from_store(ClientStore.empty(4), [0, 1])
    assert empty.stragglers == [] and empty.rates == {}


# ---------------------------------------------------------------------------
# Backend equivalence from a 10^4-client store


@pytest.fixture(scope="module")
def three_backends():
    sims = {}
    for b in ("sequential", "fleet", "sharded_fleet"):
        sim = build_population(_pop_cfg(
            backend=b, n_shards=2 if b == "sharded_fleet" else None))
        sim.run(4)
        sims[b] = sim
    return sims


def test_backends_agree_on_round_decisions(three_backends):
    ref = three_backends["sequential"].server.history
    for b, sim in three_backends.items():
        for log, rlog in zip(sim.server.history, ref):
            assert log.round_time == pytest.approx(rlog.round_time, rel=1e-9)
            assert log.stragglers == rlog.stragglers
            assert log.rates == rlog.rates


def test_backends_agree_on_params(three_backends):
    ref = three_backends["sequential"].server.params
    for b, sim in three_backends.items():
        _tree_close(sim.server.params, ref, atol=5e-6)


def test_cohorts_resample_per_round(three_backends):
    sim = three_backends["fleet"]
    a, b = sim.cohort_ids(0), sim.cohort_ids(1)
    assert not np.array_equal(a, b)
    assert sim.store.n_active == 10_000


def test_sharded_result_partials_consistent(three_backends):
    """Hierarchical contract: the fixed-order sum of the materialized
    per-shard partials IS the reduced numerator the aggregation applies."""
    sim = build_population(_pop_cfg(backend="sharded_fleet", n_shards=2))
    ids = sim.cohort_ids(0)
    clients = sim._materialize(ids)
    from repro.fl.rounds import make_backend
    backend = make_backend("sharded_fleet", sim.model_cls, clients,
                           sim.model_cls.UNIT_SPECS, n_shards=2)
    res = backend.run_round(sim.server.params, {}, {})
    pr_num, pr_w = res.shard_partials
    num = jax.tree.map(lambda a: a[0] + a[1], pr_num)
    assert _leaves_equal(num, res.num)
    np.testing.assert_array_equal(np.asarray(pr_w[0] + pr_w[1]),
                                  np.asarray(res.w_per_mask))
    # and combine(partials) == the dense stacked aggregation
    _tree_close(res.aggregate(sim.server.params),
                super(type(res), res).aggregate(sim.server.params),
                atol=1e-6)


# ---------------------------------------------------------------------------
# Bitwise determinism across device counts (CI: population-smoke runs the
# suite under XLA_FLAGS=--xla_force_host_platform_device_count=2)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (forced host devices ok)")
def test_sharded_bitwise_identical_across_device_counts():
    from jax.sharding import Mesh

    from repro.launch.mesh import make_host_mesh

    def run(mesh):
        sim = build_population(_pop_cfg(backend="sharded_fleet", n_shards=2),
                               mesh=mesh)
        ids = [sim.cohort_ids(r) for r in range(3)]
        sim.run(3)
        return ids, sim.server.params

    m1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    ids1, p1 = run(m1)
    ids2, p2 = run(make_host_mesh(data=2))
    for a, b in zip(ids1, ids2):
        np.testing.assert_array_equal(a, b)
    assert _leaves_equal(p1, p2), "aggregated params must be bitwise equal"


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (forced host devices ok)")
def test_cohort_sampling_bitwise_on_mesh_devices():
    st = ClientStore.empty(10_000).register(
        np.arange(10_000), population_speeds(10_000, seed=3),
        np.zeros(10_000))
    key = jax.random.PRNGKey(7)
    ids_host = np.asarray(st.sample_cohort(key, 64))
    on_dev1 = jax.device_put(st, jax.devices()[1])
    np.testing.assert_array_equal(
        np.asarray(on_dev1.sample_cohort(key, 64)), ids_host)


# ---------------------------------------------------------------------------
# Drift: recalibration reads the store and re-targets within one interval


def test_drift_flips_membership_and_store_rates():
    cfg = _pop_cfg(n_clients=64, cohort_size=64, backend="fleet",
                   straggler_frac_pop=0.0, seed=3)
    sim = build_population(cfg)
    sim.set_speed(5, cfg.base_speed * cfg.slow_factor)
    sim.run(2)
    assert sim.server.plan.stragglers == [5]
    assert float(sim.store.rates_of([5])[0]) < 1.0
    # runtime shift: 5 recovers, 11 degrades — one calibration interval
    # (calibrate_every=1 => the next round) flips both membership and the
    # store's assigned rates
    sim.set_speed(5, cfg.base_speed)
    sim.set_speed(11, cfg.base_speed * 1.4)
    sim.run_round()
    assert sim.server.plan.stragglers == [11]
    assert float(sim.store.rates_of([11])[0]) < 1.0
    assert float(sim.store.rates_of([5])[0]) == 1.0
    assert float(sim.store.straggler_ema[5]) > 0.0     # history remembers


def test_single_trace_across_rounds():
    """Round-over-round cohorts retrace nothing: one compiled cohort
    program serves every steady-state round (constant shapes, varying
    sample). Round 0 feeds host-resident init params; round 1+ params
    carry the program's replicated NamedSharding — that transition is the
    only compile allowed after the first."""
    from repro.fl.shard_fleet import _sharded_cohort_fn
    from repro.kernels.ops import _default_interpret
    from repro.launch.mesh import make_host_mesh

    sim = build_population(_pop_cfg(backend="sharded_fleet", n_shards=2))
    sim.run(2)
    fn = _sharded_cohort_fn(sim.model_cls,
                            make_host_mesh(data=len(jax.devices())), 2,
                            False, _default_interpret())
    n0 = fn._cache_size()
    assert n0 <= 2
    sim.run(2)
    assert fn._cache_size() == n0
