"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step + one decode step on
CPU with correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import make_optimizer

pytestmark = pytest.mark.slow    # multi-minute: tier-1 only, not the CI fast tier


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_train_decode(arch):
    cfg = get_config(arch).smoke().with_overrides(grad_accum=1)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 32
    batch = {"tokens": jnp.full((B, S), 3, jnp.int32),
             "targets": jnp.ones((B, S), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, S, cfg.d_model)).astype(cfg.dtype)

    # forward
    logits, _, aux = M.forward_seq(params, cfg, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    # one train step moves the loss
    opt = make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    step = make_train_step(cfg)
    l0 = M.loss_fn(params, cfg, batch)[0]
    p2, opt_state, metrics = step(params, opt_state, batch)
    l1 = M.loss_fn(p2, cfg, batch)[0]
    assert float(l1) == float(l1)           # not NaN
    assert float(l1) < float(l0) + 1e-3

    # prefill + decode
    pre = {k: v for k, v in batch.items() if k != "targets"}
    logits, caches, _ = M.forward_seq(params, cfg, pre, want_cache=True)
    lg, nc = M.decode_step(params, cfg, caches,
                           jnp.ones((B, 1), jnp.int32),
                           jnp.full((B,), S, jnp.int32))
    assert lg.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_matches_assignment(arch):
    """Exact assigned hyperparameters (full configs, no instantiation)."""
    cfg = get_config(arch)
    expect = {
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16,
                                      n_kv_heads=16, d_ff=8192,
                                      vocab_size=256206),
        "rwkv6-3b": dict(n_layers=32, d_model=2560, d_ff=8960,
                         vocab_size=65536),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     moe_d_ff=1408, vocab_size=102400,
                                     top_k=6, kv_lora_rank=512),
        "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48,
                            n_kv_heads=1, d_ff=24576, vocab_size=49152),
        "stablelm-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab_size=100352),
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            d_ff=6400, vocab_size=73448),
        "recurrentgemma-9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12288,
                                  vocab_size=256000),
        "command-r-35b": dict(n_layers=40, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22528, vocab_size=256000,
                              use_bias=False),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, vocab_size=32000, n_experts=128,
                            top_k=2),
        "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22016, vocab_size=65536),
    }[arch]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
