import os

# Tests must see the real device count (1 CPU); the 512-device flag is set
# ONLY by the dry-run launcher. Guard against accidental inheritance.
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""), "run pytest without the dry-run XLA_FLAGS"
