import os
import re

# Tests must not inherit the dry-run launcher's 512-virtual-device flag —
# they would silently benchmark the wrong topology. Small forced counts
# (<= 8) are legitimate: the population-smoke CI job runs the suite under
# --xla_force_host_platform_device_count=2 so the shard_map tests exercise
# a real multi-device mesh on the 1-CPU container.
_m = re.search(r"xla_force_host_platform_device_count=(\d+)",
               os.environ.get("XLA_FLAGS", ""))
assert _m is None or int(_m.group(1)) <= 8, (
    "run pytest without the dry-run XLA_FLAGS (forced device counts > 8 "
    "are reserved for the launch dry-run)")
