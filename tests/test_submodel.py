"""Sub-model extraction / embedding — the paper's core mechanism.

Property (hypothesis): for ANY keep-map, training the physically extracted
sub-model and embedding the delta back touches exactly the masked
coordinates, and extract(embed(x)) round-trips.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install the [dev] extra")
from hypothesis import given, settings, strategies as st

from repro.core import submodel as sm
from repro.models.small import FemnistCNN, ShakespeareLSTM, Vgg9


@pytest.fixture(scope="module")
def cnn_params():
    return FemnistCNN.init(jax.random.PRNGKey(0))


def _keep_map(model_cls, rng, r):
    out = {}
    for g in model_cls.UNIT_SPECS:
        k = max(1, int(round(g["size"] * r)))
        out[g["name"]] = np.sort(rng.choice(g["size"], size=k, replace=False))
    return out


@pytest.mark.parametrize("model_cls,x_shape,x_dtype", [
    (FemnistCNN, (4, 28, 28, 1), np.float32),
    (Vgg9, (4, 32, 32, 3), np.float32),
    (ShakespeareLSTM, (4, 20), np.int32),
])
def test_extract_runs_and_shrinks(model_cls, x_shape, x_dtype):
    params = model_cls.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    keep = _keep_map(model_cls, rng, 0.75)
    sub = sm.extract(params, model_cls.UNIT_SPECS, keep)
    n_sub, n_full = sm.submodel_sizes(params, model_cls.UNIT_SPECS, keep)
    assert n_sub < n_full
    x = (np.random.RandomState(1).randn(*x_shape).astype(np.float32)
         if x_dtype == np.float32
         else np.random.RandomState(1).randint(0, 70, x_shape))
    logits = model_cls.apply(sub, jnp.asarray(x))
    assert logits.shape[0] == x_shape[0]
    assert not bool(jnp.isnan(logits).any())


def test_embed_roundtrip_cnn(cnn_params):
    rng = np.random.RandomState(2)
    keep = _keep_map(FemnistCNN, rng, 0.65)
    specs = FemnistCNN.UNIT_SPECS
    sub = sm.extract(cnn_params, specs, keep)
    delta_sub = jax.tree.map(lambda x: jnp.ones_like(x), sub)
    full_delta, mask = sm.embed_delta(delta_sub, cnn_params, specs, keep)
    # re-extracting the embedded delta gives back the sub delta
    re = sm.extract(full_delta, specs, keep)
    for a, b in zip(jax.tree.leaves(re), jax.tree.leaves(delta_sub)):
        np.testing.assert_allclose(a, b)
    # delta is zero exactly where mask is zero
    for d, m in zip(jax.tree.leaves(full_delta), jax.tree.leaves(mask)):
        assert np.all((np.asarray(d) == 0) | (np.asarray(m) == 1))
        np.testing.assert_array_equal(np.asarray(d) != 0,
                                      np.asarray(m) == 1)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), r=st.floats(0.3, 0.99))
def test_embed_mask_partition_property(seed, r):
    """Masked coordinates form a partition: every group's dropped neurons are
    masked in every producer/consumer array; everything else mask==1."""
    params = ShakespeareLSTM.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(seed)
    keep = _keep_map(ShakespeareLSTM, rng, r)
    specs = ShakespeareLSTM.UNIT_SPECS
    sub = sm.extract(params, specs, keep)
    ones = jax.tree.map(jnp.ones_like, sub)
    _, mask = sm.embed_delta(ones, params, specs, keep)
    # U of lstm1 masked on both axes: kept x kept only
    m = np.asarray(mask["lstm1"]["U"])
    k1 = keep["lstm1"]
    expect = np.zeros_like(m)
    cols = sm.expand_indices(k1, 4, 128)
    expect[np.ix_(k1, cols)] = 1
    np.testing.assert_array_equal(m, expect)
    # embed layer untouched by any group: mask all ones
    assert np.all(np.asarray(mask["embed"]) == 1)


def test_tiled_expansion():
    idx = np.array([0, 2])
    np.testing.assert_array_equal(sm.expand_indices(idx, 3, 4),
                                  [0, 2, 4, 6, 8, 10])
