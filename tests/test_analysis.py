"""Static-analysis layer tests (src/repro/analysis/, DESIGN.md §11).

Three families:
  * lint fixtures — every rule gets a true-positive snippet, a clean twin
    (the idiom the fix-it recommends), and a suppressed twin, all through
    lint_source so no files are written;
  * the repo itself lints clean (the gate CI enforces);
  * dynamic contracts — the jaxpr walker catches planted f64 values and
    host callbacks, the fleet cohort program stays single-trace under
    mixed (lr, n_steps) and changing mask contents, and a small
    NaN-poisoned masked_ffn proves dropped-block dW is bitwise zero.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts
from repro.analysis.lint import RULES, lint_paths, lint_source

# ---------------------------------------------------------------------------
# lint fixtures: (rule, bad snippet, clean twin)

FIXTURES = {
    "FLD101": (
        "import jax\nimport jax.numpy as jnp\n"
        "def f(x):\n"
        "    if jnp.any(x > 0):\n"
        "        return x\n"
        "    return -x\n",
        "import jax\nimport jax.numpy as jnp\n"
        "def f(x):\n"
        "    return jnp.where(jnp.any(x > 0), x, -x)\n",
    ),
    "FLD102": (
        "import jax\nimport jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    for i in range(8):\n"
        "        x = jnp.sin(x)\n"
        "    return x\n",
        # same loop OUTSIDE any traced function: no finding
        "import jax\nimport jax.numpy as jnp\n"
        "def f(x):\n"
        "    for i in range(8):\n"
        "        x = jnp.sin(x)\n"
        "    return x\n",
    ),
    "FLD103": (
        "import jax\nimport numpy as np\n"
        "def f(fan_in):\n"
        "    return 1.0 / np.sqrt(fan_in)\n",
        "import jax\nimport math\n"
        "def f(fan_in):\n"
        "    return 1.0 / math.sqrt(fan_in)\n",
    ),
    "FLD104": (
        "import jax.numpy as jnp\n"
        "def f(d):\n"
        "    return jnp.zeros((d,))\n",
        "import jax.numpy as jnp\n"
        "def f(d):\n"
        "    return jnp.zeros((d,), jnp.float32)\n",
    ),
    "FLD105": (
        "import jax\nimport numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.asarray(x).sum()\n",
        "import jax\nimport numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x.sum()\n",
    ),
    "FLD106": (
        "from repro.core.dropout import BasePolicy\n"
        "class MyPolicy(BasePolicy):\n"
        "    pass\n",
        "from repro.core.dropout import BasePolicy, register_policy\n"
        "@register_policy('mine')\n"
        "class MyPolicy(BasePolicy):\n"
        "    pass\n",
    ),
    "FLD107": (
        "import jax\n"
        "step = jax.jit(make_train_step(cfg))\n",
        "import jax\n"
        "step = jax.jit(make_train_step(cfg), donate_argnums=())\n",
    ),
}


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_lint_true_positive(rule):
    bad, _ = FIXTURES[rule]
    hits = [f for f in lint_source(bad, f"fix_{rule}.py") if f.rule == rule]
    assert hits, f"{rule} fixture produced no finding"


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_lint_clean_twin(rule):
    _, good = FIXTURES[rule]
    hits = lint_source(good, f"clean_{rule}.py")
    assert hits == [], f"clean twin of {rule} was flagged: {hits}"


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_lint_suppression(rule):
    bad, _ = FIXTURES[rule]
    lines = bad.splitlines()
    flagged = {f.line for f in lint_source(bad, "x.py") if f.rule == rule}
    patched = "\n".join(
        ln + (f"  # fluidlint: disable={rule}" if i + 1 in flagged else "")
        for i, ln in enumerate(lines))
    assert [f for f in lint_source(patched, "x.py") if f.rule == rule] == []


def test_file_level_suppression():
    bad = FIXTURES["FLD104"][0]
    patched = "# fluidlint: disable-file=FLD104\n" + bad
    assert lint_source(patched, "x.py") == []


def test_weak_float_literals_not_flagged():
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return x * 0.5 + 1e-6\n")
    assert lint_source(src, "x.py") == []


def test_every_rule_has_fixture():
    assert set(FIXTURES) == set(RULES)


def test_repo_lints_clean():
    assert lint_paths(["src"]) == []


def test_cli_smoke(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text(FIXTURES["FLD104"][0])
    assert main(["--lint", str(bad)]) == 1
    assert "FLD104" in capsys.readouterr().out
    good = tmp_path / "good.py"
    good.write_text(FIXTURES["FLD104"][1])
    assert main(["--lint", str(good)]) == 0


# ---------------------------------------------------------------------------
# jaxpr walker

def test_walker_catches_f64():
    def f(x):
        return x * np.float64(2.0)        # strong f64 scalar upcasts x

    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.float32))
    assert contracts.walk_jaxpr(jaxpr)["f64"]


def test_walker_recurses_into_scan():
    def f(x):
        def body(c, _):
            # f64 appears in the scanned output, not the carry (scan
            # rejects carry dtype changes before the walker would see them)
            return c, c * np.float64(2.0)
        _, ys = jax.lax.scan(body, x, None, length=3)
        return ys

    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.float32))
    assert contracts.walk_jaxpr(jaxpr)["f64"]


def test_walker_catches_callback():
    def f(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)

    jaxpr = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.float32))
    assert contracts.walk_jaxpr(jaxpr)["callback"]


def test_walker_clean_program():
    def f(x):
        return jnp.sin(x) * 0.5

    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.float32))
    hits = contracts.walk_jaxpr(jaxpr)
    assert hits["f64"] == [] and hits["callback"] == []


# ---------------------------------------------------------------------------
# dynamic contracts

def test_optimizers_no_f64():
    assert contracts.check_optim_no_f64() == []


def test_models_no_f64():
    assert contracts.check_models_no_f64() == []


def test_fleet_single_trace_mixed_hparams():
    """Regression: mixed (lr, n_steps) + changed mask contents must reuse
    one compiled cohort program (the summary-level claim of DESIGN.md §8)."""
    assert contracts.check_fleet_single_trace() == []


def test_dropped_dw_bitwise_zero_small():
    """One small NaN-poisoned masked_ffn case inline (the full per-config
    sweep runs in `python -m repro.analysis --contracts`)."""
    from repro.kernels.masked_ffn import masked_ffn
    d, F, M = 8, 256, 4
    block_mask = jnp.asarray([1.0, 0.0])
    dropped = np.repeat(np.array([False, True]), 128)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(M, d).astype(np.float32))
    w_in = rng.randn(d, F).astype(np.float32)
    w_out = rng.randn(F, d).astype(np.float32)
    w_in[:, dropped] = np.nan
    w_out[dropped, :] = np.nan

    y = masked_ffn(x, jnp.asarray(w_in), jnp.asarray(w_out), block_mask,
                   act="gelu", interpret=True)
    assert np.isfinite(np.asarray(y)).all()

    def loss(wi, wo):
        return jnp.sum(masked_ffn(x, wi, wo, block_mask, act="gelu",
                                  interpret=True))
    dwi, dwo = jax.grad(loss, argnums=(0, 1))(jnp.asarray(w_in),
                                              jnp.asarray(w_out))
    assert (np.asarray(dwi)[:, dropped] == 0.0).all()
    assert (np.asarray(dwo)[dropped, :] == 0.0).all()
    assert np.isfinite(np.asarray(dwi)[:, ~dropped]).all()


def test_kernel_contracts_clean():
    from repro.analysis.kernel_contracts import run_kernel_contracts
    assert run_kernel_contracts() == []
