"""MoE: routing math, masks, capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_lib


def _cfg(**kw):
    return (get_config("deepseek-v2-lite-16b").smoke()
            .with_overrides(dtype="float32", param_dtype="float32",
                            n_shared_experts=0, **kw))


def _naive_moe(p, x2d, cfg):
    """Per-token loop reference (no capacity drops)."""
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    out = jnp.zeros_like(x2d)
    for t in range(x2d.shape[0]):
        acc = jnp.zeros(x2d.shape[1])
        for j in range(cfg.top_k):
            e = int(topi[t, j])
            h = x2d[t] @ p["w_in"][e]
            g = x2d[t] @ p["w_gate"][e]
            h = jax.nn.silu(g) * h
            acc = acc + topv[t, j] * (h @ p["w_out"][e])
        out = out.at[t].set(acc)
    return out


def test_capacity_matches_naive_when_no_drops():
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model)) * 0.5
    y, aux = moe_lib.apply_moe(p, x, cfg)
    # huge capacity factor: no token ever drops
    cfg_hi = cfg.with_overrides(moe_capacity_factor=100.0)
    y2, _ = moe_lib.apply_moe(p, x, cfg_hi)
    ref = _naive_moe(p, x[0], cfg)
    np.testing.assert_allclose(y2[0], ref, rtol=1e-4, atol=1e-4)


def test_expert_mask_excludes_experts():
    # expert-dropping concentrates load on survivors: raise capacity so no
    # token drops (FLuID raises moe_capacity_factor when dropping experts)
    cfg = _cfg(moe_capacity_factor=8.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    em = jnp.zeros((cfg.n_experts,)).at[0].set(1.0)   # only expert 0 alive
    y, _ = moe_lib.apply_moe(p, x, cfg, expert_mask=em)
    # equals computing expert 0 alone on every token
    x2d = x.reshape(-1, cfg.d_model)
    h = x2d @ p["w_in"][0]
    g = x2d @ p["w_gate"][0]
    ref = (jax.nn.silu(g) * h) @ p["w_out"][0]
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), ref,
                               rtol=1e-3, atol=1e-3)


def test_neuron_mask_zeroes_units():
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    nm_all = jnp.ones((cfg.n_experts, cfg.moe_ff))
    nm_none = jnp.zeros((cfg.n_experts, cfg.moe_ff))
    y1, _ = moe_lib.apply_moe(p, x, cfg, neuron_mask=nm_all)
    y0, _ = moe_lib.apply_moe(p, x, cfg, neuron_mask=nm_none)
    ybase, _ = moe_lib.apply_moe(p, x, cfg)
    np.testing.assert_allclose(y1, ybase, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y0, 0.0, atol=1e-6)


def test_aux_loss_balanced_is_small():
    cfg = _cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    _, aux = moe_lib.apply_moe(p, x, cfg)
    assert 0.5 < float(aux) < 4.0   # ~1 when perfectly balanced
