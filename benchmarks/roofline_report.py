"""Roofline report: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (one row per arch x shape, single-pod)."""
from __future__ import annotations

import glob
import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath="experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*__sp.json"))):
        d = json.load(open(f))
        rows.append(d)
    rows.sort(key=lambda d: (d["arch"], ORDER.index(d["shape"])))
    return rows


def fmt_row(d):
    r = d.get("roofline", d["uncorrected"])
    mem = d["memory"]
    peak = (mem["argument_bytes_per_device"] + mem["temp_bytes_per_device"]
            + mem["output_bytes_per_device"] - mem["alias_bytes_per_device"])
    tc, tm, tl = r["t_compute"], r["t_memory"], r["t_collective"]
    dom = max(tc, tm, tl)
    frac = tc / dom if dom else 0.0
    ratio = d.get("useful_flops_ratio", float("nan"))
    return {
        "arch": d["arch"], "shape": d["shape"],
        "t_compute_ms": tc * 1e3, "t_memory_ms": tm * 1e3,
        "t_collective_ms": tl * 1e3, "bottleneck": r["bottleneck"],
        "roofline_frac": frac,                 # compute-time / dominant-time
        "useful_flops_ratio": ratio,
        "mem_gib": peak / 2**30,
        "coll": r.get("coll_by_type", {}),
    }


def table(rows):
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'bound':>10s} {'RLfrac':>6s} {'useful':>6s} "
           f"{'GiB/dev':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for d in rows:
        f = fmt_row(d)
        lines.append(
            f"{f['arch']:24s} {f['shape']:12s} {f['t_compute_ms']:8.2f}m "
            f"{f['t_memory_ms']:8.2f}m {f['t_collective_ms']:8.2f}m "
            f"{f['bottleneck']:>10s} {f['roofline_frac']:6.2f} "
            f"{f['useful_flops_ratio']:6.2f} {f['mem_gib']:7.2f}")
    return "\n".join(lines)


def masked_train_table(path="BENCH_masked_train.json"):
    """Render BENCH_masked_train.json (benchmarks/masked_train_bench.py)
    against the roofline FLOP model: one row per dropout rate with the
    measured dense/kernel step times and `flop_ratio`, the roofline-
    predicted step-time ratio the compiled-backend gate applies to."""
    if not os.path.exists(path):
        return None
    d = json.load(open(path))
    g = d["gate"]
    hdr = (f"{'rate':>5s} {'kept':>5s} {'dense_ms':>9s} {'kernel_ms':>10s} "
           f"{'meas_ratio':>10s} {'flop_ratio':>10s}")
    lines = [f"masked-train sweep ({d['model']}; interpret={d['interpret']})",
             hdr, "-" * len(hdr)]
    for r in d["results"]:
        mr = r["measured_ratio_vs_dense_r0"]
        lines.append(f"{r['rate']:5.2f} {r['kept_neurons']:5d} "
                     f"{r['dense_ms']:9.3f} {r['kernel_ms']:10.3f} "
                     f"{(mr if mr is not None else float('nan')):10.3f} "
                     f"{r['flop_ratio']:10.4f}")
    lines.append(f"gate: rate {g['rate']} predicted ratio "
                 f"{g['predicted_kernel_ratio_at_gate_rate']} <= "
                 f"{g['target_ratio']} ({g['applies_on']})")
    if d["interpret"]:
        lines.append("note: " + d["note"])
    return "\n".join(lines)


def main():
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print(table(rows))
    mt = masked_train_table()
    if mt:
        print()
        print(mt)
    # candidates
    fr = [(fmt_row(d)["roofline_frac"], d["arch"], d["shape"]) for d in rows]
    fr.sort()
    print("\nworst roofline fraction:", fr[:5])
    cb = [(fmt_row(d)["t_collective_ms"]
           / max(sum((fmt_row(d)[k] for k in
                      ("t_compute_ms", "t_memory_ms"))), 1e-9),
           d["arch"], d["shape"]) for d in rows]
    cb.sort(reverse=True)
    print("most collective-bound:", cb[:5])


def _advice(f):
    b = f["bottleneck"]
    if b == "collective":
        return ("shrink weight/cache gathers: TP-resident weights, "
                "sequence-sharded cache (see §Perf serve_seqcache)")
    if b == "memory":
        if f["shape"] in ("decode_32k", "long_500k"):
            return ("fuse cache read+score+update (Pallas decode_gqa); "
                    "avoid f32 dot-operand converts (TPU-native bf16)")
        return ("fuse elementwise chains / remat policy; larger per-device "
                "batch amortizes weight traffic")
    return "increase arithmetic intensity (larger tiles, fewer reshards)"


def markdown(rows):
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound "
           "| useful-FLOPs | GiB/dev | lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        f = fmt_row(d)
        out.append(
            f"| {f['arch']} | {f['shape']} | {f['t_compute_ms']:.1f} "
            f"| {f['t_memory_ms']:.1f} | {f['t_collective_ms']:.1f} "
            f"| {f['bottleneck']} | {f['useful_flops_ratio']:.2f} "
            f"| {f['mem_gib']:.1f} | {_advice(f)} |")
    return "\n".join(out)


def mp_summary(dirpath="experiments/dryrun"):
    import glob as g
    out = []
    for fp in sorted(g.glob(os.path.join(dirpath, "*__mp.json"))):
        d = json.load(open(fp))
        mem = d["memory"]
        peak = (mem["argument_bytes_per_device"] + mem["temp_bytes_per_device"]
                + mem["output_bytes_per_device"]
                - mem["alias_bytes_per_device"]) / 2**30
        out.append((d["arch"], d["shape"], round(peak, 2),
                    d["compile_s"]))
    return out


if __name__ == "__main__":
    main()
