"""Paper-experiment drivers — one function per FLuID table/figure.

Each returns a dict of results; benchmarks/run.py prints the CSV summary and
experiments/run_paper_validation.py runs the bigger validation pass whose
numbers land in EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                 build_simulation)

METHODS = ("random", "ordered", "invariant")


def _sim(workload, *, n_clients=5, straggler_ids=(0,), method="invariant",
         fixed_rate=None, straggler_frac=None, n_data=400, slow_factor=1.3,
         seed=0):
    """All paper drivers build through one typed-config helper."""
    return build_simulation(SimulationConfig(
        workload=workload, policy=method, fixed_rate=fixed_rate,
        straggler_frac=straggler_frac, seed=seed,
        cohort=CohortConfig(n_clients=n_clients, straggler_ids=straggler_ids,
                            n_data=n_data, slow_factor=slow_factor)))


def table2_accuracy(workload="femnist", rates=(0.75,), rounds=8,
                    n_clients=5, n_data=600, seeds=(0,)) -> Dict:
    """Table 2: accuracy of Random/Ordered/Invariant at fixed sub-model
    sizes (straggler trains the r-sized sub-model)."""
    out = {}
    for r in rates:
        for m in METHODS:
            accs = []
            for s in seeds:
                sim = _sim(workload, n_clients=n_clients, method=m,
                           fixed_rate=r, n_data=n_data, seed=s)
                hist = sim.server.run(rounds, eval_every=rounds)
                accs.append(hist[-1].accuracy)
            out[(m, r)] = (float(np.mean(accs)), float(np.std(accs)))
    return out


def fig4a_straggler_time(workload="femnist", rounds=6, n_data=400,
                         slow_factor=1.3, seed=0) -> Dict:
    """Fig 4a: straggler round time lands near T_target after FLuID."""
    sim = _sim(workload, n_data=n_data, slow_factor=slow_factor, seed=seed)
    hist = sim.server.run(rounds)
    before = [h for h in hist if not h.rates]
    after = [h for h in hist if h.rates]
    return {
        "t_straggler_before": float(np.mean([h.round_time for h in before])),
        "t_straggler_after": float(np.mean([h.straggler_time
                                            for h in after])),
        "t_target": float(np.mean([h.t_target for h in after])),
        "within_10pct": bool(np.mean([h.straggler_time for h in after])
                             <= 1.10 * np.mean([h.t_target for h in after])),
    }


def fig4b_dynamic_stragglers(workload="femnist", rounds=12, n_data=400,
                             seed=0) -> Dict:
    """Fig 4b: a different client becomes slow mid-run; FLuID re-adapts.
    Compares total time: no-dropout vs static-straggler vs dynamic FLuID."""
    def run(method, dynamic_policy):
        sim = _sim(workload, method=method, n_data=n_data, seed=seed)
        total, switched = 0.0, False
        for i in range(rounds):
            if i == rounds // 2 and not switched:
                sim.set_speed(0, 10.0)
                sim.set_speed(3, 13.5)
                switched = True
                if dynamic_policy == "static":
                    # freeze the plan: keep treating client 0 as straggler
                    sim.server.cfg = sim.server.cfg.__class__(
                        **{**sim.server.cfg.__dict__,
                           "calibrate_every": 10_000})
            h = sim.server.run_round()
            total += h.round_time
        return total
    t_none = run("none", "dynamic")
    t_static = run("invariant", "static")
    t_fluid = run("invariant", "dynamic")
    return {"t_baseline": t_none, "t_static_straggler": t_static,
            "t_fluid": t_fluid,
            "speedup_vs_baseline": t_none / t_fluid,
            "speedup_vs_static": t_static / t_fluid}


def fig6_invariant_evolution(workload="femnist", rounds=10, n_data=400,
                             seed=0) -> Dict:
    """Fig 6 / App A.1: invariant fraction grows over training."""
    sim = _sim(workload, n_data=n_data, seed=seed)
    hist = sim.server.run(rounds)
    fr = [h.invariant_frac for h in hist]
    return {"invariant_frac_by_round": fr,
            "frac_at_30pct_training": fr[max(1, int(rounds * 0.3))],
            "final_frac": fr[-1]}


def table3_threshold(workload="femnist", rounds=6, n_data=400,
                     thresholds=(0.01, 0.03, 0.05, 0.1), seed=0) -> Dict:
    """Table 3 / App A.2: higher threshold -> more invariant neurons."""
    from repro.core import invariant as inv
    sim = _sim(workload, n_data=n_data, seed=seed)
    sim.server.run(rounds)
    # recompute per-client stats at the last round
    import jax
    prev = sim.server.params
    per_client = []
    for c in sim.clients:
        u = c.train(prev)
        new = jax.tree.map(lambda p, d: p + d, prev, u.delta)
        per_client.append(inv.neuron_stats(prev, new,
                                           sim.model_cls.UNIT_SPECS))
    total = sum(g["size"] for g in sim.model_cls.UNIT_SPECS)
    out = {}
    for th in thresholds:
        out[th] = inv.count_invariant(per_client, th) / total
    return out


def fig5_scalability(workload="femnist", n_clients=10, straggler_frac=0.2,
                     rounds=6, n_data=800, seed=0) -> Dict:
    """Fig 5: many clients, 20% stragglers; invariant vs baselines."""
    k = max(1, int(n_clients * straggler_frac))
    out = {}
    for m in METHODS + ("none",):
        sim = _sim(workload, n_clients=n_clients,
                   straggler_ids=tuple(range(k)), method=m,
                   straggler_frac=straggler_frac, n_data=n_data, seed=seed)
        hist = sim.server.run(rounds, eval_every=rounds)
        out[m] = {"accuracy": hist[-1].accuracy,
                  "mean_round_time": float(np.mean(
                      [h.round_time for h in hist[1:]]))}
    return out


def insight_oneshot_pruning(workload="femnist", rounds=15, n_data=1500,
                            rates=(0.9, 0.75, 0.5), seed=0) -> Dict:
    """Direct test of the paper's core insight: neurons flagged invariant
    contribute least. Train a full model federatedly, then one-shot-extract
    sub-models by each policy (no retraining) and measure the accuracy
    drop. Invariant selection should lose the least."""
    import jax
    import jax.numpy as jnp

    from repro.core import invariant as inv
    from repro.core import submodel as sm
    from repro.core.dropout import DropoutPolicy

    sim = _sim(workload, method="none", n_data=n_data, seed=seed)
    sim.server.run(rounds)
    params = sim.server.params
    specs = sim.model_cls.UNIT_SPECS

    # one extra profiling round for invariant stats
    per_client = []
    for c in sim.clients:
        u = c.train(params)
        new = jax.tree.map(lambda p, d: p + d, params, u.delta)
        per_client.append(inv.neuron_stats(params, new, specs))
    th = inv.initial_threshold(per_client) * 4

    pol = {m: DropoutPolicy(m, specs, seed=seed)
           for m in ("random", "ordered", "invariant")}
    pol["invariant"].observe(per_client, th)

    xt = jnp.asarray(sim.ds.x_test)
    yt = jnp.asarray(sim.ds.y_test)

    def acc(p):
        return float((jnp.argmax(sim.model_cls.apply(p, xt), -1)
                      == yt).mean())

    out = {"full": acc(params)}
    for r in rates:
        for m, p in pol.items():
            sub = sm.extract(params, specs, p.keep_map(r))
            out[f"{m}@r{r}"] = acc(sub)
    return out
