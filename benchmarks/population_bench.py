"""Population-scale round sweep -> BENCH_population.json.

Measures end-to-end FLuID round wall-clock when cohorts are sampled from a
10^5-client ClientStore (fl/population.py): cohort sizes 200 -> 5000, the
vectorized fleet backend vs the sharded executor (fl/shard_fleet.py) on a
1-device mesh and on the full device mesh. The headline column is
per-device client throughput (clients trained per second per device) — the
number that has to stay flat as devices are added for the sharded path to
claim linear scaling.

Honesty note: this container has ONE physical CPU. Multi-device rows are
produced with XLA's forced host platform device count (--devices N), which
splits that core into N virtual devices sharing the same ALUs — they
demonstrate the sharded program's correctness and measure its partitioning
overhead, NOT a speedup. On a real multi-chip backend the same harness
(run with the native device count) produces the scaling rows. The JSON
records `forced_host_devices` so a quoted number can't hide this.

--devices N   force N virtual host devices (must be first; set before jax
              imports so the flag takes effect).
--smoke       ~2 min CI mode: 2*10^4-client store, cohort 64, asserts the
              harness produces valid rows on every backend.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

# Must happen before anything imports jax.
if "--devices" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}").strip()

COHORTS = (200, 1000, 5000)
STORE_N = 100_000


def _build(cohort, backend, n_shards, store_n, mesh=None, seed=0):
    from repro.fl.population import PopulationConfig, build_population
    cfg = PopulationConfig(
        n_clients=store_n, cohort_size=cohort, workload="synth",
        backend=backend, n_shards=n_shards, n_partitions=64,
        samples_per_partition=100, seed=seed)
    return build_population(cfg, mesh=mesh)


def _time_rounds(sim, warmup=2, iters=2):
    """Steady-state seconds per full round (sample -> materialize ->
    cohort program -> aggregate -> store scatter). Two warmup rounds: the
    first compiles, the second absorbs the host-array -> NamedSharding
    params transition (see contracts.check_population_single_trace)."""
    sim.run(warmup)
    t0 = time.perf_counter()
    sim.run(iters)
    return (time.perf_counter() - t0) / iters


def _row(cohort, backend, n_shards, store_n, mesh=None, iters=2):
    import jax
    sim = _build(cohort, backend, n_shards, store_n, mesh=mesh)
    dt = _time_rounds(sim, iters=iters)
    n_dev = 1 if mesh is None and backend != "sharded_fleet" else (
        sim.mesh.shape["data"] if sim.mesh is not None
        else len(jax.devices()))
    cps = cohort / dt
    return {
        "cohort": cohort, "backend": backend, "n_shards": n_shards,
        "data_devices": n_dev,
        "round_ms": round(dt * 1e3, 1),
        "clients_per_sec": round(cps, 1),
        "clients_per_sec_per_device": round(cps / n_dev, 1),
        "stragglers_last_round": len(sim.server.plan.stragglers),
    }


def sweep(cohorts, store_n, iters=2):
    import jax

    from repro.launch.mesh import make_host_mesh
    n_dev = len(jax.devices())
    rows = []
    for c in cohorts:
        rows.append(_row(c, "fleet", None, store_n, iters=iters))
        one = make_host_mesh(data=1)
        rows.append(_row(c, "sharded_fleet", 2, store_n, mesh=one,
                         iters=iters))
        if n_dev > 1:
            rows.append(_row(c, "sharded_fleet", n_dev, store_n,
                             iters=iters))
        print(f"  cohort {c}: " + ", ".join(
            f"{r['backend']}@D{r['data_devices']}={r['round_ms']}ms"
            for r in rows[-3 if n_dev > 1 else -2:]), file=sys.stderr)
    return rows


def main(argv):
    import jax
    smoke = "--smoke" in argv
    if smoke:
        rows = sweep((64,), store_n=20_000, iters=1)
        for r in rows:
            assert r["round_ms"] > 0 and r["clients_per_sec"] > 0, r
        assert {r["backend"] for r in rows} >= {"fleet", "sharded_fleet"}
        print(f"population smoke OK: {len(rows)} rows, devices="
              f"{len(jax.devices())}, "
              + ", ".join(f"{r['backend']}@D{r['data_devices']}="
                          f"{r['round_ms']}ms" for r in rows))
        return
    rows = sweep(COHORTS, store_n=STORE_N)
    forced = "xla_force_host_platform_device_count" in os.environ.get(
        "XLA_FLAGS", "")
    payload = {
        "bench": "population",
        "store_clients": STORE_N,
        "cohorts": list(COHORTS),
        "workload": "synth (32-d MLP, 64 IID partitions x 100 samples)",
        "devices": len(jax.devices()),
        "forced_host_devices": forced,
        "note": ("forced host devices split ONE physical core: the D>1 "
                 "rows measure sharding overhead, not speedup — rerun on "
                 "a multi-chip backend for scaling numbers"
                 if forced or len(jax.devices()) == 1 else
                 "native multi-device backend: per-device throughput is "
                 "the scaling claim"),
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "results": rows,
    }
    out = (pathlib.Path(__file__).resolve().parent.parent
           / "BENCH_population.json")
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main(sys.argv[1:])
