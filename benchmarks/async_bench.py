"""Async buffered rounds vs the synchronous barrier -> BENCH_async.json.

The claim under test (DESIGN.md §13): under heavy-tailed client latencies,
asynchronous buffered aggregation (fl/async_rounds.py) reaches the
synchronous fleet's round-40 accuracy in strictly less SIMULATED wall-clock,
because the barrier pays max-of-cohort lognormal latency every round while
the buffer pays the K-th order statistic of a larger in-flight pool.

Both arms share the identical population: same ClientStore speeds (10%
slow-band stragglers), same per-client lognormal tail (PopulationConfig.
tail_sigma — applied in SimClient._sim_time, so the barrier baseline
experiences the same latency distribution, not a handicapped copy), same
invariant-dropout calibration. Time is emulated seconds from the client
speed model: sum of per-round barrier maxima for sync, the EventLoop clock
for async. Real (host) seconds are recorded for provenance only.

--devices N   force N virtual host devices (must be first; set before jax
              imports so the flag takes effect).
--smoke       ~2 min CI mode: small store, short horizon, asserts the
              async arm actually reaches the sync target accuracy.
"""
from __future__ import annotations

import json
import math
import os
import pathlib
import sys
import time

# Must happen before anything imports jax.
if "--devices" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}").strip()

SYNC_ROUNDS = 40
TAIL_SIGMA = 0.6


def _base_cfg(smoke: bool):
    from repro.fl.population import PopulationConfig
    if smoke:
        return dict(n_clients=2_000, cohort_size=16, workload="synth",
                    n_partitions=16, samples_per_partition=40,
                    straggler_frac_pop=0.1, tail_sigma=TAIL_SIGMA, seed=0), \
            PopulationConfig
    return dict(n_clients=20_000, cohort_size=32, workload="synth",
                n_partitions=64, samples_per_partition=100,
                straggler_frac_pop=0.1, tail_sigma=TAIL_SIGMA, seed=0), \
        PopulationConfig


def run_sync(base, PopulationConfig, rounds):
    from repro.fl.population import build_population
    sim = build_population(PopulationConfig(backend="fleet", **base))
    t0 = time.perf_counter()
    hist = sim.run(rounds, eval_every=max(1, rounds // 8))
    real = time.perf_counter() - t0
    accs = [(h.round, h.accuracy) for h in hist if not math.isnan(h.accuracy)]
    return {
        "backend": "fleet",
        "rounds": rounds,
        "client_updates": rounds * base["cohort_size"],
        "sim_seconds": round(sum(h.round_time for h in hist), 2),
        "final_accuracy": round(accs[-1][1], 4),
        "accuracy_trajectory": [(r, round(a, 4)) for r, a in accs],
        "real_seconds": round(real, 1),
    }


def run_async(base, PopulationConfig, target_acc, buffer_k, concurrency,
              max_buffers, eval_every=2):
    from repro.fl.async_rounds import AsyncConfig
    from repro.fl.population import build_population
    from repro.core.straggler import ArrivalModel
    acfg = AsyncConfig(buffer_k=buffer_k, concurrency=concurrency,
                       staleness_exponent=0.5,
                       arrival=ArrivalModel())   # tails live client-side
    sim = build_population(PopulationConfig(backend="async",
                                            async_cfg=acfg, **base))
    t0 = time.perf_counter()
    accs, reached_at = [], None
    for step in range(max_buffers):
        log = sim.run_round(eval_now=(step % eval_every == eval_every - 1))
        if not math.isnan(log.accuracy):
            accs.append((step, round(log.accuracy, 4)))
            if log.accuracy >= target_acc:
                reached_at = step
                break
    real = time.perf_counter() - t0
    hist = sim.server.history
    return {
        "backend": "async",
        "buffer_k": buffer_k,
        "concurrency": concurrency,
        "staleness_exponent": acfg.staleness_exponent,
        "buffers": len(hist),
        "client_updates": len(hist) * buffer_k,
        "sim_seconds": round(sim.clock, 2),
        "target_accuracy": round(target_acc, 4),
        "reached_target": reached_at is not None,
        "reached_at_buffer": reached_at,
        "final_accuracy": accs[-1][1] if accs else None,
        "accuracy_trajectory": accs[-12:],
        "staleness_max": max(h.staleness_max for h in hist),
        "staleness_mean_last": round(hist[-1].staleness_mean, 3),
        "dropouts": sim.backend.total_drops,
        "real_seconds": round(real, 1),
    }


def main(argv):
    import jax
    smoke = "--smoke" in argv
    base, PopulationConfig = _base_cfg(smoke)
    rounds = 8 if smoke else SYNC_ROUNDS
    print(f"sync arm: {rounds} barrier rounds, cohort "
          f"{base['cohort_size']}, tail_sigma={TAIL_SIGMA}",
          file=sys.stderr)
    sync = run_sync(base, PopulationConfig, rounds)
    print(f"  sync: acc={sync['final_accuracy']} in "
          f"{sync['sim_seconds']} sim s", file=sys.stderr)
    k = base["cohort_size"] // 2
    async_row = run_async(base, PopulationConfig, sync["final_accuracy"],
                          buffer_k=k, concurrency=4 * base["cohort_size"],
                          max_buffers=40 if smoke else 10 * rounds)
    print(f"  async: acc={async_row['final_accuracy']} in "
          f"{async_row['sim_seconds']} sim s "
          f"({async_row['buffers']} buffers, "
          f"max staleness {async_row['staleness_max']})", file=sys.stderr)

    assert async_row["reached_target"], (
        "async arm never reached the sync target accuracy — raise "
        "max_buffers or check staleness weighting", async_row)
    speedup = sync["sim_seconds"] / async_row["sim_seconds"]
    if smoke:
        print(f"async smoke OK: target {sync['final_accuracy']} reached at "
              f"buffer {async_row['reached_at_buffer']}, "
              f"sim speedup x{speedup:.2f}")
        return
    assert async_row["sim_seconds"] < sync["sim_seconds"], (
        "acceptance: async must reach the sync round-40 accuracy in "
        "strictly less simulated wall-clock", sync, async_row)

    payload = {
        "bench": "async",
        "store_clients": base["n_clients"],
        "workload": "synth (32-d MLP)",
        "tail_sigma": TAIL_SIGMA,
        "straggler_frac_pop": base["straggler_frac_pop"],
        "sim_speedup_to_target": round(speedup, 2),
        "note": ("simulated seconds from the shared client speed model: "
                 "sync pays max-of-cohort lognormal latency per round, "
                 "async pays the K-th arrival of a "
                 f"{4 * base['cohort_size']}-client in-flight pool"),
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "results": [sync, async_row],
    }
    out = (pathlib.Path(__file__).resolve().parent.parent
           / "BENCH_async.json")
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main(sys.argv[1:])
