"""Benchmark harness — one entry per paper table/figure + kernel micros +
the dry-run roofline digest. Prints ``name,us_per_call,derived`` CSV.

Fast by default (CPU-sized runs proving each harness end-to-end); set
BENCH_FULL=1 for the long validation pass (also available as
``python -m experiments.run_paper_validation``).
"""
from __future__ import annotations

import os
import time

import numpy as np

FULL = os.environ.get("BENCH_FULL", "0") == "1"


def _timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def bench_table2_accuracy():
    from benchmarks.paper_experiments import table2_accuracy
    rates = (0.95, 0.75, 0.5) if FULL else (0.75,)
    rounds = 25 if FULL else 6
    out, us = _timed(table2_accuracy, rates=rates, rounds=rounds,
                     n_data=1200 if FULL else 500)
    inv = np.mean([v[0] for (m, r), v in out.items() if m == "invariant"])
    rnd = np.mean([v[0] for (m, r), v in out.items() if m == "random"])
    return us, f"acc_invariant={inv:.3f};acc_random={rnd:.3f}"


def bench_fig4a_straggler_time():
    from benchmarks.paper_experiments import fig4a_straggler_time
    out, us = _timed(fig4a_straggler_time, rounds=10 if FULL else 5,
                     n_data=400)
    return us, (f"before={out['t_straggler_before']:.2f}s;"
                f"after={out['t_straggler_after']:.2f}s;"
                f"target={out['t_target']:.2f}s;"
                f"within10pct={out['within_10pct']}")


def bench_fig4b_dynamic():
    from benchmarks.paper_experiments import fig4b_dynamic_stragglers
    out, us = _timed(fig4b_dynamic_stragglers, rounds=12 if FULL else 8,
                     n_data=400)
    return us, (f"speedup_vs_baseline={out['speedup_vs_baseline']:.3f};"
                f"speedup_vs_static={out['speedup_vs_static']:.3f}")


def bench_fig6_invariant_evolution():
    from benchmarks.paper_experiments import fig6_invariant_evolution
    out, us = _timed(fig6_invariant_evolution, rounds=12 if FULL else 6,
                     n_data=400)
    return us, (f"frac_at_30pct={out['frac_at_30pct_training']:.3f};"
                f"final={out['final_frac']:.3f}")


def bench_table3_threshold():
    from benchmarks.paper_experiments import table3_threshold
    out, us = _timed(table3_threshold, rounds=5 if FULL else 3, n_data=400)
    s = ";".join(f"th{t}={v:.3f}" for t, v in out.items())
    return us, s


def bench_fig5_scalability():
    from benchmarks.paper_experiments import fig5_scalability
    out, us = _timed(fig5_scalability,
                     n_clients=20 if FULL else 8,
                     rounds=10 if FULL else 4,
                     n_data=1000 if FULL else 600)
    return us, ";".join(f"{m}={v['accuracy']:.3f}" for m, v in out.items()
                        if v["accuracy"] == v["accuracy"])


def bench_fleet_scaling(out_path=None):
    """Clients vs wall-clock, sequential vs fleet backend -> BENCH_fleet.json.

    Same seeds, same rounds; only the execution engine differs. Wall-clock
    includes compilation — that is the point: the fleet backend compiles one
    cohort program while the sequential loop pays per-client dispatch and
    per-sub-shape recompiles."""
    import json
    import pathlib

    import jax

    from repro.fl.simulation import (CohortConfig, SimulationConfig,
                                     build_simulation)

    out_path = out_path or (pathlib.Path(__file__).resolve().parent.parent
                            / "BENCH_fleet.json")
    fleet_sizes = (5, 50, 200) if FULL else (5, 50)
    rounds = 5
    per_client = 10      # cross-device regime: many clients, small shards
    results = []
    for n in fleet_sizes:
        row = {"n_clients": n}
        for backend in ("sequential", "fleet"):
            sim = build_simulation(SimulationConfig(
                workload="femnist", backend=backend, policy="invariant",
                seed=0, cohort=CohortConfig(n_clients=n, straggler_ids=(0,),
                                            n_data=per_client * n)))
            t0 = time.perf_counter()
            sim.server.run(rounds)
            row[f"{backend}_s"] = round(time.perf_counter() - t0, 3)
        row["speedup"] = round(row["sequential_s"] / row["fleet_s"], 2)
        results.append(row)
    payload = {
        "bench": "fleet_scaling", "workload": "femnist",
        "method": "invariant", "rounds": rounds,
        "samples_per_client": per_client,
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "results": results,
    }
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    us = sum(r["sequential_s"] + r["fleet_s"] for r in results) * 1e6
    return us, ";".join(
        f"C{r['n_clients']}:seq={r['sequential_s']}s,"
        f"fleet={r['fleet_s']}s,x{r['speedup']}" for r in results)


def bench_kernel_invariant_stats():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import invariant_stats
    k = jax.random.PRNGKey(0)
    w0 = jax.random.normal(k, (1024, 1024), jnp.float32)
    w1 = w0 + 0.01
    invariant_stats(w0, w1).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        invariant_stats(w0, w1).block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    return us, "shape=1024x1024;interpret=True"


def bench_kernel_masked_ffn():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import masked_ffn
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (256, 512), jnp.float32)
    win = jax.random.normal(jax.random.fold_in(k, 1), (512, 1024)) * 0.02
    wout = jax.random.normal(jax.random.fold_in(k, 2), (1024, 512)) * 0.02
    mask = jnp.asarray(np.random.RandomState(0).randint(0, 2, 8),
                       jnp.int32)
    masked_ffn(x, win, wout, mask, act="gelu").block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        masked_ffn(x, win, wout, mask, act="gelu").block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    return us, f"kept_blocks={int(mask.sum())}/8;interpret=True"


def bench_kernel_decode_gqa():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import decode_gqa
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (4, 16, 128), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(k, 1), (4, 2048, 2, 128))
    vc = jax.random.normal(jax.random.fold_in(k, 2), (4, 2048, 2, 128))
    ln = jnp.full((4,), 2048, jnp.int32)
    decode_gqa(q, kc, vc, ln).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        decode_gqa(q, kc, vc, ln).block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    return us, "B4_H16_C2048;interpret=True"


def bench_masked_train():
    """Dense vs differentiable-kernel cohort step -> BENCH_masked_train.json
    (full sweep under BENCH_FULL=1; parity-only smoke otherwise)."""
    from benchmarks.masked_train_bench import sweep
    t0 = time.perf_counter()
    rows = (sweep() if FULL else sweep(n_clients=2, per_client=8, iters=1))
    us = (time.perf_counter() - t0) * 1e6
    worst = max(r["max_delta_err"] for r in rows)
    at_half = next(r["flop_ratio"] for r in rows if r["rate"] == 0.5)
    return us, (f"rates={len(rows)};max_delta_err={worst:.1e};"
                f"flop_ratio@0.5={at_half}")


def bench_roofline_digest():
    from benchmarks.roofline_report import fmt_row, load
    t0 = time.perf_counter()
    try:
        rows = load()
    except Exception:
        return 0.0, "no dryrun results (run repro.launch.dryrun first)"
    us = (time.perf_counter() - t0) * 1e6
    if not rows:
        return us, "no dryrun results"
    worst = min(rows, key=lambda d: fmt_row(d)["roofline_frac"])
    f = fmt_row(worst)
    return us, (f"combos={len(rows)};worst={f['arch']}/{f['shape']}"
                f";frac={f['roofline_frac']:.3f}")


def bench_kernel_rwkv_chunk():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import rwkv_chunk_scan
    k = jax.random.PRNGKey(0)
    B, S, H, N = 2, 128, 4, 64
    r = jax.random.normal(k, (B, S, H, N))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, N))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, N))
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 3),
                                      (B, S, H, N)) - 1.0)
    u = jnp.zeros((H, N))
    rwkv_chunk_scan(r, kk, v, logw, u, chunk=64)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        rwkv_chunk_scan(r, kk, v, logw, u, chunk=64)[0].block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    return us, "B2_S128_H4_N64;interpret=True"


BENCHES = [
    ("table2_accuracy", bench_table2_accuracy),
    ("fig4a_straggler_time", bench_fig4a_straggler_time),
    ("fig4b_dynamic_stragglers", bench_fig4b_dynamic),
    ("fig6_invariant_evolution", bench_fig6_invariant_evolution),
    ("table3_threshold", bench_table3_threshold),
    ("fig5_scalability", bench_fig5_scalability),
    ("fleet_scaling", bench_fleet_scaling),
    ("kernel_invariant_stats", bench_kernel_invariant_stats),
    ("kernel_masked_ffn", bench_kernel_masked_ffn),
    ("kernel_decode_gqa", bench_kernel_decode_gqa),
    ("kernel_rwkv_chunk", bench_kernel_rwkv_chunk),
    ("masked_train", bench_masked_train),
    ("roofline_digest", bench_roofline_digest),
]


def main() -> None:
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        try:
            us, derived = fn()
            print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
