"""Serving benchmark: engine decode throughput vs the Python-loop baseline.

Measures decode tok/s for {batch 1, 8, 32} x {dense, r=0.5, mixed-rate}
through launch/serving.ServeEngine (one jitted lax.scan chunk per dispatch,
masks as data) and, at each batch size, the synchronous Python-loop decoder
from launch/serve.serve (one jit dispatch per token, dense only). Writes
BENCH_serve.json at the repo root.

Apples-to-apples: both paths run the same smoke config, greedy argmax, same
prompt/gen lengths; engine runs are uniform-length requests so the slot
batch stays full (the continuous-batching ragged case is exercised by
tests/test_serving.py, not timed here).

``--smoke`` runs one tiny mixed-rate batch and asserts non-zero throughput
plus single-trace decode — the CI serve gate.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax


def _engine_run(cfg, batch, rates, prompt_len, gen_len, seed=0):
    """Returns (tok_per_s, summary) for 2*batch uniform-length requests."""
    from repro.launch.serving import ServeEngine, ServeRequest, rate_masks
    from repro.models import model as model_lib
    params = model_lib.init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params, batch_size=batch,
                      max_prompt_len=prompt_len, max_gen_len=gen_len,
                      chunk=min(8, gen_len))
    mask_of = {r: (None if r >= 1.0 else rate_masks(cfg, r, seed=seed))
               for r in rates}
    rng = np.random.RandomState(seed)

    def submit_wave():
        for i in range(2 * batch):
            toks = rng.randint(0, min(cfg.vocab_size, 256), (prompt_len,),
                               dtype=np.int32)
            eng.submit(ServeRequest(toks, gen_len=gen_len,
                                    masks=mask_of[rates[i % len(rates)]]))

    submit_wave()        # warmup wave: compiles prefill/insert/decode
    eng.run()
    for k in eng.stats:
        eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0
    submit_wave()        # timed wave
    eng.run()
    s = eng.summary()
    return s["tok_per_s"], s


def _baseline_run(cfg, batch, prompt_len, gen_len, seed=0):
    """Python-loop decode tok/s (dense; one dispatch per token)."""
    from repro.launch.serve import serve
    serve(cfg, batch, prompt_len, gen_len, seed=seed)          # warmup
    _, stats = serve(cfg, batch, prompt_len, gen_len, seed=seed)
    return stats["tok_per_s"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run + assertions (CI gate), no JSON")
    ap.add_argument("--batches", default="1,8,32")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import get_config
    cfg = get_config(args.arch).smoke()

    if args.smoke:
        tps, s = _engine_run(cfg, batch=2, rates=(1.0, 0.5, 0.25),
                             prompt_len=8, gen_len=8)
        assert tps > 0, f"no decode throughput: {s}"
        assert s["trace_counts"]["decode"] == 1, \
            f"decode retraced: {s['trace_counts']}"
        print(f"serve smoke OK: {tps:.1f} tok/s, "
              f"trace_counts={s['trace_counts']}")
        return

    mixes = {"dense": (1.0,), "r0.5": (0.5,),
             "mixed": (1.0, 0.5, 0.25)}
    results = []
    for batch in (int(b) for b in args.batches.split(",")):
        row = {"batch": batch}
        for name, rates in mixes.items():
            tps, s = _engine_run(cfg, batch, rates, args.prompt_len,
                                 args.gen_len)
            row[f"engine_{name}_tok_s"] = round(tps, 1)
            row["trace_counts"] = s["trace_counts"]
        row["baseline_loop_tok_s"] = round(
            _baseline_run(cfg, batch, args.prompt_len, args.gen_len), 1)
        row["speedup_vs_loop"] = round(
            row["engine_dense_tok_s"] / max(row["baseline_loop_tok_s"],
                                            1e-9), 2)
        print(row)
        results.append(row)

    out = {"bench": "serve_engine", "arch": args.arch, "config": "smoke",
           "prompt_len": args.prompt_len, "gen_len": args.gen_len,
           "jax": jax.__version__, "device": jax.devices()[0].platform,
           "results": results}
    path = args.out or os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(path)}")


if __name__ == "__main__":
    main()
