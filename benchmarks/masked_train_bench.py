"""Masked-train sweep -> BENCH_masked_train.json (DESIGN.md §10 gate).

Measures the *training* step of a fleet cohort at dropout rates
0.0/0.25/0.5/0.75, dense `mask * params` path vs the differentiable Pallas
kernel path (`FleetEngine(use_kernels=True)`), and reports both against the
roofline-style FLOP model: the fraction of the step's matmul FLOPs that
live in the maskable FFN determines the best-case step-time ratio at each
rate. The FLuID claim being gated: a rate-r sub-model should take ~r of
the maskable work, forward AND backward — not just the modeled sim-time.

On this CPU container the kernels run in Pallas interpret mode, which is
correctness-only (per-tile Python dispatch dominates), so the measured
interpret timings do NOT exhibit the speedup; the JSON records them for
provenance next to `flop_ratio`, the compiled-backend prediction the
acceptance gate (rate 0.5 <= 0.7x dense) applies to. On a real TPU the
same sweep (this file, interpret=False via jax.default_backend) produces
measured ratios tracking `flop_ratio`.

--smoke: tiny cohort, asserts kernel/dense delta parity and that the sweep
machinery produces a valid row (CI `kernel-grad` job).
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import numpy as np

RATES = (0.0, 0.25, 0.5, 0.75)
GATE = {"rate": 0.5, "target_ratio": 0.7,
        "applies_on": "compiled (non-interpret) backends"}


def _build_engine(n_clients, per_client, use_kernels, seed=0):
    import jax

    from repro.fl.client import FleetClient
    from repro.fl.fleet import FleetEngine
    from repro.models.kernel_models import KernelMLP

    rng = np.random.RandomState(seed)
    x = rng.randn(n_clients * per_client, 28, 28, 1).astype(np.float32)
    y = rng.randint(0, 62, n_clients * per_client).astype(np.int32)
    clients = [FleetClient(i, KernelMLP,
                           x[i * per_client:(i + 1) * per_client],
                           y[i * per_client:(i + 1) * per_client],
                           speed=10.0, batch_size=per_client, lr=0.05,
                           local_epochs=1, seed=seed)
               for i in range(n_clients)]
    params = KernelMLP.init(jax.random.PRNGKey(seed))
    engine = FleetEngine(KernelMLP, clients, KernelMLP.UNIT_SPECS,
                         use_kernels=use_kernels)
    return engine, params


def _keep_maps(engine, rate):
    """Every client a straggler at `rate`, 128-block-aligned keep sets
    (the transformer_hooks block128 policy) so dropped blocks are whole
    skippable tiles."""
    from repro.models.kernel_models import KernelMLP
    F = KernelMLP.hidden
    kept = int(round((1.0 - rate) * F / 128)) * 128
    kept = max(kept, 128) if rate < 1.0 else 0
    km = {"ffn": np.arange(kept)}
    return {c.id: km for c in engine.clients}, kept


def _time_cohort(engine, params, keep_maps, iters=3):
    """Steady-state seconds per cohort train step (the compiled program
    only — host-side shard staging and mask-bank dedupe are excluded)."""
    import jax
    import jax.numpy as jnp

    xs, ys, sw = engine._stacked_data()
    bank, idx, _ = engine._mask_bank(params, keep_maps)
    lrs = jnp.asarray(engine.lrs)

    def once():
        out = engine._run(params, bank, idx, xs, ys, sw, lrs, engine.steps)
        jax.tree.leaves(out)[0].block_until_ready()
        return out
    once()                                        # compile + warm caches
    t0 = time.perf_counter()
    for _ in range(iters):
        out = once()
    return (time.perf_counter() - t0) / iters, out


def _flop_model(engine, kept_f):
    """Matmul FLOPs of one client's local step, fwd+bwd (bwd = 2x fwd for
    each matmul: dx and dW). KernelMLP: enc (784->64) and head (64->62)
    are unmaskable; the 64->F->64 FFN scales with kept_f."""
    from repro.models.kernel_models import KernelMLP
    d, F = KernelMLP.d, KernelMLP.hidden
    M = engine.bs
    fixed = 2 * M * 784 * d + 2 * M * d * 62          # fwd enc + head
    ffn = 2 * M * d * kept_f * 2                      # fwd w_in + w_out
    return 3 * (fixed + ffn), 3 * (fixed + 2 * M * d * F * 2)


def sweep(n_clients=4, per_client=16, iters=3):
    dense_eng, params = _build_engine(n_clients, per_client,
                                      use_kernels=False)
    kern_eng, _ = _build_engine(n_clients, per_client, use_kernels=True)
    rows = []
    dense_base = None
    for rate in RATES:
        keep_maps, kept = _keep_maps(dense_eng, rate)
        t_dense, out_d = _time_cohort(dense_eng, params, keep_maps, iters)
        t_kern, out_k = _time_cohort(kern_eng, params, keep_maps, iters)
        import jax
        err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                  for a, b in zip(jax.tree.leaves(out_d),
                                  jax.tree.leaves(out_k)))
        masked_flops, dense_flops = _flop_model(dense_eng, kept)
        if rate == 0.0:
            dense_base = t_dense
        rows.append({
            "rate": rate, "kept_neurons": kept,
            "dense_ms": round(t_dense * 1e3, 3),
            "kernel_ms": round(t_kern * 1e3, 3),
            "measured_ratio_vs_dense_r0": round(
                t_kern / dense_base, 3) if dense_base else None,
            "flop_ratio": round(masked_flops / dense_flops, 4),
            "max_delta_err": err,
        })
    return rows


def main(argv):
    import jax

    smoke = "--smoke" in argv
    if smoke:
        rows = sweep(n_clients=2, per_client=8, iters=1)
    else:
        rows = sweep()
    for r in rows:
        assert r["max_delta_err"] < 1e-4, (
            f"kernel/dense cohort divergence at rate {r['rate']}: "
            f"{r['max_delta_err']}")
    interpret = jax.default_backend() != "tpu"
    payload = {
        "bench": "masked_train",
        "model": "KernelMLP (784-enc / 64->1024->64 masked FFN / 62-head)",
        "rates": list(RATES),
        "gate": dict(GATE, predicted_kernel_ratio_at_gate_rate=next(
            r["flop_ratio"] for r in rows if r["rate"] == GATE["rate"])),
        "interpret": interpret,
        "note": ("interpret-mode CPU timings are per-tile Python dispatch, "
                 "overhead-dominated; the gate applies to flop_ratio on "
                 "compiled backends where step time tracks matmul FLOPs"
                 if interpret else
                 "compiled backend: measured ratios are the gate"),
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "results": rows,
    }
    at_gate = payload["gate"]["predicted_kernel_ratio_at_gate_rate"]
    assert at_gate <= GATE["target_ratio"], (
        f"FLOP model at rate {GATE['rate']} is {at_gate}, above the "
        f"{GATE['target_ratio']} gate — the maskable fraction regressed")
    if smoke:
        print(f"masked_train smoke OK: parity at rates {list(RATES)}, "
              f"flop_ratio@{GATE['rate']}={at_gate} <= "
              f"{GATE['target_ratio']}")
        return
    out = (pathlib.Path(__file__).resolve().parent.parent
           / "BENCH_masked_train.json")
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main(sys.argv[1:])
